"""Zero-copy aliasing rules (``alias-writeable``, ``alias-mutation``).

The wire decode path wraps received bytes with ``np.frombuffer`` and
hands the views to the aggregation fold — they alias the transport
buffer and are **borrow-only by contract** (fl/messages.py).  Likewise
``tile_source(...)`` tiles and the delta-decode base chunks
(``.base``-receiver ``f64_chunk``/``decode_chunk`` reads, the
``_chunk_cache``) are shared, cached state: an in-place write corrupts
every other borrower *and* the fig5 bitwise contract.

- ``alias-writeable``: a ``np.frombuffer`` view must either be copied
  immediately (``np.frombuffer(...).copy()``) or have
  ``view.flags.writeable = False`` set in the same function before use —
  bytes-backed views are born read-only but bytearray/memoryview-backed
  ones (real receive buffers) are writable unless frozen.
- ``alias-mutation``: any write into a tracked borrow-only view —
  subscript/slice stores, ``+=`` style in-place ops, mutating ndarray
  methods (``fill``/``sort``/...), ``np.copyto(view, ...)``,
  ``out=view``, or re-enabling ``flags.writeable``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, Optional

from repro.analysis.core import Check, Finding, Module

_NDARRAY_MUTATORS = {"fill", "sort", "partition", "put", "itemset",
                     "setfield", "resize", "byteswap"}
#: chained calls on a fresh frombuffer result that materialize a copy
_COPYING_CHAIN = {"copy", "tobytes", "astype"}
_BORROW_CALLS = {"f64_chunk", "decode_chunk"}


def _call_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


class _FuncScan:
    """Single forward pass over one function body (no flow analysis:
    straight-line discipline is the convention being enforced)."""

    def __init__(self, mod: Module, body):
        self.mod = mod
        self.body = body
        self.tracked: Dict[str, str] = {}    # var -> 'frombuffer' | 'view'
        self.frozen: set = set()
        self.def_line: Dict[str, int] = {}
        self.base_aliases: set = set()       # locals bound from `<x>.base`
        self.findings = []

    def run(self):
        for stmt in self.body:
            self._stmt(stmt)
        for name, kind in self.tracked.items():
            if kind == "frombuffer" and name not in self.frozen:
                line = self.def_line[name]
                self.findings.append(Finding(
                    "alias-writeable", self.mod.path, line, 0,
                    f"np.frombuffer view {name!r} is never frozen: set "
                    f"`{name}.flags.writeable = False` before use (or "
                    ".copy() immediately) — bytearray-backed receive "
                    "buffers stay writable otherwise"))
        return self.findings

    # ------------------------------------------------------------------
    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name):
                self._bind(tgt.id, stmt.value, stmt.lineno)
                self._scan_expr(stmt.value)
                return
            if self._freeze_target(tgt, stmt.value):
                return
            self._check_store_target(tgt, stmt.lineno)
            self._scan_expr(stmt.value)
            return
        if isinstance(stmt, ast.AugAssign):
            tgt = stmt.target
            if isinstance(tgt, ast.Name) and tgt.id in self.tracked:
                self._mutation(tgt.id, stmt.lineno, "augmented assignment")
            else:
                self._check_store_target(tgt, stmt.lineno)
            self._scan_expr(stmt.value)
            return
        # recurse into compound statements, expressions, returns...
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.stmt):
                self._stmt(child)
            elif isinstance(child, ast.expr):
                self._scan_expr(child)
            elif isinstance(child, (ast.withitem, ast.excepthandler)):
                for sub in ast.iter_child_nodes(child):
                    if isinstance(sub, ast.stmt):
                        self._stmt(sub)
                    elif isinstance(sub, ast.expr):
                        self._scan_expr(sub)

    def _bind(self, name: str, value: ast.expr, line: int) -> None:
        kind = self._classify(value)
        if kind:
            self.tracked[name] = kind
            self.def_line[name] = line
            self.frozen.discard(name)
        else:
            self.tracked.pop(name, None)
            self.frozen.discard(name)
            if (isinstance(value, ast.Attribute)
                    and value.attr == "base"):
                self.base_aliases.add(name)

    def _classify(self, value: ast.expr) -> Optional[str]:
        attr = _call_attr(value)
        if attr == "frombuffer":
            return "frombuffer"
        if attr == "tile_source":
            return "view"
        if attr in _BORROW_CALLS:
            recv = value.func.value
            if (isinstance(recv, ast.Attribute) and recv.attr == "base") \
                    or (isinstance(recv, ast.Name)
                        and recv.id in self.base_aliases):
                return "view"
        if isinstance(value, ast.Attribute):
            if value.attr == "_chunk_cache":
                return "view"
            # X.data / X.scales of a tracked view is still the view
            if (value.attr in ("data", "scales")
                    and isinstance(value.value, ast.Name)
                    and value.value.id in self.tracked):
                return "view"
        if isinstance(value, ast.Subscript):
            base = value.value
            if isinstance(base, ast.Attribute) \
                    and base.attr == "_chunk_cache":
                return "view"
            # slicing a tracked view yields a view of the same buffer
            if isinstance(base, ast.Name) and base.id in self.tracked:
                return "view"
        return None

    # ------------------------------------------------------------------
    def _freeze_target(self, tgt: ast.expr, value: ast.expr) -> bool:
        """``X.flags.writeable = <bool>`` — freeze or illegal thaw."""
        if (isinstance(tgt, ast.Attribute) and tgt.attr == "writeable"
                and isinstance(tgt.value, ast.Attribute)
                and tgt.value.attr == "flags"
                and isinstance(tgt.value.value, ast.Name)):
            name = tgt.value.value.id
            if name in self.tracked:
                if isinstance(value, ast.Constant) and value.value is False:
                    self.frozen.add(name)
                else:
                    self._mutation(name, tgt.lineno,
                                   "re-enabling flags.writeable")
            return True
        return False

    def _check_store_target(self, tgt: ast.expr, line: int) -> None:
        while isinstance(tgt, (ast.Subscript, ast.Attribute)):
            if isinstance(tgt, ast.Subscript) \
                    and isinstance(tgt.value, ast.Name) \
                    and tgt.value.id in self.tracked:
                self._mutation(tgt.value.id, line, "subscript store")
                return
            tgt = tgt.value

    def _scan_expr(self, expr: ast.expr) -> None:
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute):
                if (f.attr in _NDARRAY_MUTATORS
                        and isinstance(f.value, ast.Name)
                        and f.value.id in self.tracked):
                    self._mutation(f.value.id, node.lineno,
                                   f".{f.attr}() in-place method")
                if (f.attr == "copyto"
                        and node.args
                        and isinstance(node.args[0], ast.Name)
                        and node.args[0].id in self.tracked):
                    self._mutation(node.args[0].id, node.lineno,
                                   "np.copyto destination")
            for kw in node.keywords:
                if (kw.arg == "out" and isinstance(kw.value, ast.Name)
                        and kw.value.id in self.tracked):
                    self._mutation(kw.value.id, node.lineno,
                                   "out= destination")

    def _mutation(self, name: str, line: int, how: str) -> None:
        self.findings.append(Finding(
            "alias-mutation", self.mod.path, line, 0,
            f"in-place write ({how}) into borrow-only view {name!r}: "
            "frombuffer/tile_source/base-chunk views alias shared "
            "buffers — materialize a copy first"))


class AliasCheck(Check):
    rules = ("alias-writeable", "alias-mutation")

    def visit(self, mod: Module) -> Iterable[Finding]:
        if "frombuffer" not in mod.text and "tile_source" not in mod.text \
                and "_chunk_cache" not in mod.text:
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from _FuncScan(mod, node.body).run()
        # inline (unbound) frombuffer calls can never be frozen: require
        # an immediate copy-producing chain
        parents = {}
        for node in ast.walk(mod.tree):
            for child in ast.iter_child_nodes(node):
                parents[child] = node
        for node in ast.walk(mod.tree):
            if _call_attr(node) == "frombuffer":
                par = parents.get(node)
                bound = isinstance(par, ast.Assign) and len(
                    par.targets) == 1 and isinstance(
                    par.targets[0], ast.Name)
                chained = (isinstance(par, ast.Attribute)
                           and par.attr in _COPYING_CHAIN | {"reshape",
                                                             "view"})
                if not bound and not chained:
                    yield Finding(
                        "alias-writeable", mod.path, node.lineno,
                        node.col_offset,
                        "unbound np.frombuffer result cannot be frozen: "
                        "bind it and set flags.writeable = False, or "
                        "chain .copy() immediately")

"""Lock discipline for the threaded runtime (``lock-order``,
``guarded-by``).

Per class, the checker collects ``self._x = threading.Lock/RLock/
Condition(...)`` attributes, then walks every method tracking which of
those locks are held (``with self._x:``), treating nested ``def``/
``lambda`` bodies as fresh contexts (closures run later, typically on
another thread, with nothing held).

``lock-order``: nested acquisitions produce edges in a per-class lock
graph — directly nested ``with`` blocks, and ``self.m()`` calls made
while holding a lock contribute edges to every lock ``m`` (transitively)
acquires.  A cycle is a potential deadlock; a self-edge on a
non-reentrant ``Lock`` is a guaranteed one.

``guarded-by``: an attribute annotated ``# guarded-by: _lock`` on its
``__init__`` assignment must only be written while holding that lock;
an *unannotated* attribute written both under some lock and under none
(outside ``__init__``) is flagged as mixed discipline — the unlocked
site is the race.  A ``# guarded-by: _lock`` on a method ``def`` line
declares a lock-held helper: its body is analyzed with the lock held,
and every call site must actually hold it.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import Check, Finding, Module

_LOCK_FACTORIES = {"Lock": False, "RLock": True, "Condition": True,
                   "Semaphore": False, "BoundedSemaphore": False}

#: container-mutating method names that count as writes to the receiver
_MUTATORS = {"append", "appendleft", "add", "extend", "insert", "update",
             "setdefault", "pop", "popitem", "popleft", "remove",
             "discard", "clear"}


def _self_attr(node: ast.AST) -> Optional[str]:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _write_targets(stmt: ast.stmt) -> List[ast.expr]:
    if isinstance(stmt, ast.Assign):
        return list(stmt.targets)
    if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        return [stmt.target]
    return []


class _MethodScan(ast.NodeVisitor):
    """One method (or nested-function) body: lock scopes, writes, calls."""

    def __init__(self, lock_attrs: Set[str]):
        self.lock_attrs = lock_attrs
        self.held: List[str] = []
        #: (outer, inner, line) for directly nested with-acquisitions
        self.nest_edges: List[Tuple[str, str, int]] = []
        #: every lock this method acquires anywhere
        self.acquires: Set[str] = set()
        #: (held_snapshot, called_method, line)
        self.calls: List[Tuple[Tuple[str, ...], str, int]] = []
        #: attr -> list of (held_snapshot, line)
        self.writes: Dict[str, List[Tuple[Tuple[str, ...], int]]] = {}
        self.nested: List[ast.AST] = []

    # ---------------------------------------------------------- contexts
    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        for item in node.items:
            attr = _self_attr(item.context_expr)
            if attr in self.lock_attrs:
                for outer in self.held:
                    self.nest_edges.append((outer, attr, node.lineno))
                self.held.append(attr)
                self.acquires.add(attr)
                pushed += 1
        for stmt in node.body:
            self.visit(stmt)
        for _ in range(pushed):
            self.held.pop()

    def visit_FunctionDef(self, node):          # closure: fresh context
        self.nested.append(node)

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef

    # ------------------------------------------------------------ events
    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        attr = _self_attr(f)
        if attr is not None:
            self.calls.append((tuple(self.held), attr, node.lineno))
        elif isinstance(f, ast.Attribute) and f.attr in _MUTATORS:
            written = self._receiver_attr(f.value)
            if written is not None:
                self._record_write(written, node.lineno)
        self.generic_visit(node)

    def _receiver_attr(self, recv: ast.AST) -> Optional[str]:
        """self.X or self.X[...] as a mutator receiver -> 'X'."""
        if isinstance(recv, ast.Subscript):
            recv = recv.value
        return _self_attr(recv)

    def _record_write(self, attr: str, line: int) -> None:
        self.writes.setdefault(attr, []).append((tuple(self.held), line))

    def _visit_write_stmt(self, stmt) -> None:
        for tgt in _write_targets(stmt):
            attr = _self_attr(tgt)
            if attr is None and isinstance(tgt, ast.Subscript):
                attr = _self_attr(tgt.value)
            if attr is not None and attr not in self.lock_attrs:
                self._record_write(attr, stmt.lineno)
            # deletes/tuple targets: walk for self attrs
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                for el in tgt.elts:
                    a = _self_attr(el)
                    if a is not None:
                        self._record_write(a, stmt.lineno)
        self.generic_visit(stmt)

    visit_Assign = _visit_write_stmt
    visit_AugAssign = _visit_write_stmt
    visit_AnnAssign = _visit_write_stmt

    def visit_Delete(self, node: ast.Delete) -> None:
        for tgt in node.targets:
            attr = _self_attr(tgt)
            if attr is None and isinstance(tgt, ast.Subscript):
                attr = _self_attr(tgt.value)
            if attr is not None:
                self._record_write(attr, node.lineno)
        self.generic_visit(node)


class _ClassFacts:
    def __init__(self, mod: Module, node: ast.ClassDef):
        self.mod = mod
        self.node = node
        self.lock_attrs: Dict[str, bool] = {}      # attr -> reentrant?
        self.guards: Dict[str, str] = {}           # attr -> lock name
        self.method_guards: Dict[str, str] = {}    # lock-held helpers
        self.scans: Dict[str, List[_MethodScan]] = {}

    def collect(self) -> None:
        for m in self.node.body:
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for stmt in ast.walk(m):
                    if isinstance(stmt, ast.Assign) and isinstance(
                            stmt.value, ast.Call):
                        f = stmt.value.func
                        if (isinstance(f, ast.Attribute)
                                and isinstance(f.value, ast.Name)
                                and f.value.id == "threading"
                                and f.attr in _LOCK_FACTORIES):
                            for tgt in stmt.targets:
                                attr = _self_attr(tgt)
                                if attr:
                                    self.lock_attrs[attr] = \
                                        _LOCK_FACTORIES[f.attr]
        if not self.lock_attrs:
            return
        # guarded-by annotations on __init__ assignment lines
        for m in self.node.body:
            if (isinstance(m, ast.FunctionDef)
                    and m.name == "__init__"):
                for stmt in ast.walk(m):
                    if isinstance(stmt, ast.Assign) \
                            and stmt.lineno in self.mod.guard_notes:
                        for tgt in stmt.targets:
                            attr = _self_attr(tgt)
                            if attr:
                                self.guards[attr] = \
                                    self.mod.guard_notes[stmt.lineno]
        for m in self.node.body:
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for ln in (m.lineno, m.lineno - 1):
                    guard = self.mod.guard_notes.get(ln)
                    if guard in self.lock_attrs:
                        self.method_guards[m.name] = guard
                        break
                self.scans[m.name] = self._scan_contexts(m)

    def _scan_contexts(self, m) -> List[_MethodScan]:
        """Scan a method plus its nested defs, each as a fresh context.

        Only the method's own top-level context inherits its declared
        guard: closures typically run later, on another thread."""
        out: List[_MethodScan] = []
        queue: List[ast.AST] = [m]
        while queue:
            fn = queue.pop()
            scan = _MethodScan(set(self.lock_attrs))
            if fn is m and m.name in self.method_guards:
                scan.held.append(self.method_guards[m.name])
            body = fn.body if not isinstance(fn, ast.Lambda) else [
                ast.Expr(fn.body)]
            for stmt in body:
                scan.visit(stmt)
            out.append(scan)
            queue.extend(scan.nested)
        return out


class LockCheck(Check):
    rules = ("lock-order", "guarded-by")

    def scope(self, mod: Module) -> bool:
        return any(
            (isinstance(n, ast.Import)
             and any(a.name == "threading" for a in n.names))
            or (isinstance(n, ast.ImportFrom)
                and n.module == "threading")
            for n in ast.walk(mod.tree))

    def visit(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                facts = _ClassFacts(mod, node)
                facts.collect()
                if facts.lock_attrs:
                    yield from self._check_order(facts)
                    yield from self._check_guards(facts)

    # ------------------------------------------------------------------
    def _check_order(self, facts: _ClassFacts) -> Iterable[Finding]:
        # transitive lock set per method (call-graph fixpoint)
        acq: Dict[str, Set[str]] = {
            name: set().union(*(s.acquires for s in scans))
            for name, scans in facts.scans.items()}
        calls: Dict[str, Set[str]] = {
            name: {c for s in scans for _, c, _ in s.calls}
            for name, scans in facts.scans.items()}
        changed = True
        while changed:
            changed = False
            for name in acq:
                for callee in calls.get(name, ()):
                    extra = acq.get(callee, set()) - acq[name]
                    if extra:
                        acq[name] |= extra
                        changed = True
        edges: Dict[Tuple[str, str], int] = {}
        for name, scans in facts.scans.items():
            for s in scans:
                for outer, inner, line in s.nest_edges:
                    edges.setdefault((outer, inner), line)
                for held, callee, line in s.calls:
                    for outer in held:
                        for inner in acq.get(callee, ()):
                            edges.setdefault((outer, inner), line)
        cls = facts.node.name
        # self-edge on a non-reentrant lock: certain deadlock
        for (a, b), line in sorted(edges.items()):
            if a == b and not facts.lock_attrs.get(a, True):
                yield Finding(
                    "lock-order", facts.mod.path, line, 0,
                    f"{cls}.{a} is a non-reentrant Lock re-acquired "
                    "while already held (self-deadlock); use RLock or "
                    "restructure")
        # cycle detection over distinct-lock edges
        graph: Dict[str, Set[str]] = {}
        for (a, b), _ in edges.items():
            if a != b:
                graph.setdefault(a, set()).add(b)
        for cycle in _find_cycles(graph):
            locs = [edges.get((cycle[i], cycle[(i + 1) % len(cycle)]))
                    for i in range(len(cycle))]
            line = min(loc for loc in locs if loc is not None)
            path = " -> ".join(cycle + (cycle[0],))
            yield Finding(
                "lock-order", facts.mod.path, line, 0,
                f"lock-order inversion in {cls}: acquisition cycle "
                f"{path} can deadlock; pick one global order")

    def _check_guards(self, facts: _ClassFacts) -> Iterable[Finding]:
        cls = facts.node.name
        # lock-held helpers must be called with their lock actually held
        for name, scans in facts.scans.items():
            for s in scans:
                for held, callee, line in s.calls:
                    guard = facts.method_guards.get(callee)
                    if guard is not None and guard not in held:
                        yield Finding(
                            "guarded-by", facts.mod.path, line, 0,
                            f"{cls}.{callee}() is declared guarded-by: "
                            f"{guard} but {name}() calls it without "
                            "holding it")
        sites: Dict[str, List[Tuple[str, Tuple[str, ...], int]]] = {}
        for name, scans in facts.scans.items():
            if name == "__init__":
                continue
            for s in scans:
                for attr, ws in s.writes.items():
                    for held, line in ws:
                        sites.setdefault(attr, []).append(
                            (name, held, line))
        for attr, ws in sorted(sites.items()):
            guard = facts.guards.get(attr)
            if guard is not None:
                for name, held, line in ws:
                    if guard not in held:
                        yield Finding(
                            "guarded-by", facts.mod.path, line, 0,
                            f"{cls}.{attr} is annotated guarded-by: "
                            f"{guard} but {name}() writes it without "
                            "holding it")
                continue
            locked = [w for w in ws if w[1]]
            unlocked = [w for w in ws if not w[1]]
            if locked and unlocked:
                lock_names = sorted({ln for _, held, _ in locked
                                     for ln in held})
                for name, _, line in unlocked:
                    yield Finding(
                        "guarded-by", facts.mod.path, line, 0,
                        f"{cls}.{attr} is written under "
                        f"{'/'.join(lock_names)} elsewhere but {name}() "
                        "writes it with no lock held — annotate the "
                        "attribute `# guarded-by: <lock>` and fix the "
                        "unlocked write")


def _find_cycles(graph: Dict[str, Set[str]]) -> List[Tuple[str, ...]]:
    """Distinct elementary cycles (small graphs: simple DFS, dedup by
    canonical rotation)."""
    cycles: Set[Tuple[str, ...]] = set()

    def dfs(start: str, node: str, path: List[str],
            on_path: Set[str]) -> None:
        for nxt in sorted(graph.get(node, ())):
            if nxt == start:
                cyc = tuple(path)
                k = cyc.index(min(cyc))
                cycles.add(cyc[k:] + cyc[:k])
            elif nxt not in on_path and nxt > start:
                path.append(nxt)
                on_path.add(nxt)
                dfs(start, nxt, path, on_path)
                on_path.discard(nxt)
                path.pop()

    for start in sorted(graph):
        dfs(start, start, [start], {start})
    return sorted(cycles)

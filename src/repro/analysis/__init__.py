"""Project-specific invariant checkers (stdlib ``ast`` only, no deps).

The repo carries invariants that ordinary linters cannot see (see
``docs/INVARIANTS.md``): the bitwise-reproducibility contract of the
aggregation fold, the lock discipline of the threaded runtime, the
borrow-only zero-copy decode views, the ``0xF0``–``0xFF`` codec-byte
registry, and the monotonic-deadline rule.  ``repro.analysis`` turns
them into machine-checked findings:

    PYTHONPATH=src python -m repro.analysis src/ tests/ --strict

Findings can be suppressed per line with a justified pragma::

    something_flagged()  # repro: allow[rule-id] reason=why it is safe

A bare ``allow`` without a ``reason=`` is itself a finding
(``bare-allow``), as is an ``allow`` naming an unknown rule
(``unknown-rule``) — suppressions must stay auditable.
"""
from repro.analysis.core import (  # noqa: F401
    ALL_RULES, Finding, main, run_analysis,
)

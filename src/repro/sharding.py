"""Logical-axis sharding rules.

Every tensor in the system is annotated with *logical* axis names
("embed", "heads", "mlp", "vocab", "batch", ...).  :func:`spec_for` maps
those to a :class:`jax.sharding.PartitionSpec` for a concrete mesh, with
**divisibility fallback**: if a dimension is not divisible by the product of
its assigned mesh axes, mesh axes are dropped (innermost first) until it is.
This is what lets one rule table serve ten architectures whose head counts /
vocab sizes are not all multiples of 16.

Rule table (MaxText-style 2-D "fsdp + tensor"):

  batch   -> ("pod", "data")      activations' batch dim
  seq     -> None                 (sequence kept whole except long-decode cache)
  cache_seq -> "data"             flash-decoding style KV-page sharding
  embed   -> ("data", "model")    weight d_model dim  == FSDP storage sharding
  embed_nofsdp -> None            small models: replicate instead of FSDP
  heads   -> "model"              attention-head tensor parallelism
  kv_heads-> "model"
  mlp     -> "model"              d_ff tensor parallelism
  experts -> "model"              expert parallelism
  vocab   -> "model"
  head_dim, qk, v, lora, state -> None

The fallback drops axes *for that tensor only* and records the decision so
the dry-run can report which tensors fell back (useful in §Roofline).
"""
from __future__ import annotations

import logging
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

logger = logging.getLogger(__name__)

Axes = Tuple[Optional[Tuple[str, ...]], ...]

# logical axis -> tuple of mesh axes (in sharding-priority order)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "cache_seq": ("data",),
    "embed": ("data", "model"),
    "embed_expert": ("data", "model"),  # expert-weight d_model (decode keeps FSDP)
    "embed_tensor": ("model",),      # d_model as a *contraction output* (o_proj in)
    "embed_nofsdp": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "head_dim": (),
    "qk": (),
    "v": (),
    "lora": (),
    "state": (),
    "stack": (),                     # stacked-layer leading dim (scan)
    "window": (),
    "frames": (),
    "pos": (),
    "conv": (),
}

# Decisions recorded by the most recent spec_for calls: name -> (requested, used)
FALLBACKS: Dict[str, Tuple[str, str]] = {}


def _mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
    fsdp: bool = True,
    name: str = "",
) -> P:
    """PartitionSpec for `shape` annotated with `logical_axes`."""
    rules = rules or DEFAULT_RULES
    sizes = _mesh_axis_sizes(mesh)
    assert len(logical_axes) == len(shape), (logical_axes, shape, name)

    def rule_for(logical: str) -> Tuple[str, ...]:
        key = "embed_nofsdp" if (not fsdp and logical == "embed") else logical
        return tuple(a for a in rules.get(key, ()) if a in sizes)

    # Two-pass allocation: dims whose rule names a single mesh axis (tensor
    # parallelism: heads/mlp/experts/vocab) claim axes first; multi-axis
    # rules (FSDP "embed") then take whatever remains.  A mesh axis is used
    # at most once per tensor (GSPMD requirement).
    order = sorted(
        range(len(shape)),
        key=lambda i: (len(rule_for(logical_axes[i])) if logical_axes[i] else 99),
    )
    used_axes: set = set()
    entries: list = [None] * len(shape)
    for i in order:
        logical, dim = logical_axes[i], shape[i]
        if logical is None:
            continue
        mesh_axes = [a for a in rule_for(logical) if a not in used_axes]
        kept = list(mesh_axes)
        while kept:  # divisibility fallback: drop least-priority axes
            prod = 1
            for a in kept:
                prod *= sizes[a]
            if dim % prod == 0:
                break
            kept.pop()
        if kept != mesh_axes and name:
            FALLBACKS[f"{name}:{logical}"] = (
                "x".join(mesh_axes) or "-", "x".join(kept) or "-")
        used_axes.update(kept)
        if len(kept) == 1:
            entries[i] = kept[0]
        elif kept:
            entries[i] = tuple(kept)
    return P(*entries)


def named_sharding(mesh: Mesh, logical_axes: Sequence[Optional[str]],
                   shape: Sequence[int], **kw) -> NamedSharding:
    return NamedSharding(mesh, spec_for(logical_axes, shape, mesh, **kw))


def tree_specs(axes_tree, shape_tree, mesh: Mesh, fsdp: bool = True):
    """Map spec_for over parallel pytrees of logical-axes and shapes."""
    return jax.tree.map(
        lambda ax, shp: spec_for(ax, shp, mesh, fsdp=fsdp),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def clear_fallbacks() -> None:
    FALLBACKS.clear()


# ---------------------------------------------------------------------------
# Activation sharding constraints (MaxText-style)
# ---------------------------------------------------------------------------
# Weight shardings alone let GSPMD propagate an FSDP (feature-dim) sharding
# onto activations, which destroys batch sharding and replicates attention
# scores (observed +100GB/device).  The launcher installs the ambient mesh +
# batch axes here; model code calls `constrain_*` at the residual-stream
# boundaries.  No-ops when nothing is installed (CPU smoke tests).
_ACT_MESH: list = [None, (), ("model",)]   # [mesh, batch_axes, vocab_axes]


def set_activation_mesh(mesh: Optional[Mesh], batch_axes: Tuple[str, ...] = (),
                        vocab_axes: Tuple[str, ...] = ("model",)) -> None:
    _ACT_MESH[0] = mesh
    _ACT_MESH[1] = tuple(batch_axes)
    _ACT_MESH[2] = tuple(vocab_axes)


def _wsc(x, spec):
    mesh = _ACT_MESH[0]
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_batch(x):
    """Constrain the residual stream: dim0=batch on the batch axes and —
    when divisible — dim1=seq on "model" (Megatron-style sequence
    parallelism: the saved remat stream shrinks model_size x; GSPMD inserts
    the per-layer seq all-gather / reduce-scatter pair)."""
    mesh, baxes = _ACT_MESH[0], _ACT_MESH[1]
    if mesh is None or not baxes:
        return x
    sizes = _mesh_axis_sizes(mesh)
    prod = 1
    for a in baxes:
        prod *= sizes.get(a, 1)
    if x.shape[0] % prod != 0:
        return x
    seq = None
    if (x.ndim >= 3 and "model" in sizes and x.shape[1] > 1
            and x.shape[1] % sizes["model"] == 0 and "model" not in baxes):
        seq = "model"
    return _wsc(x, P(baxes, seq, *([None] * (x.ndim - 2))))


def constrain_logits(x):
    """(B, S, V): batch axes on dim0, vocab axes on the last dim."""
    mesh, baxes, vaxes = _ACT_MESH
    if mesh is None:
        return x
    sizes = _mesh_axis_sizes(mesh)
    bprod = 1
    for a in baxes:
        bprod *= sizes.get(a, 1)
    vprod = 1
    for a in vaxes:
        vprod *= sizes.get(a, 1)
    b = baxes if (baxes and x.shape[0] % bprod == 0) else None
    v = vaxes if (vaxes and x.shape[-1] % vprod == 0) else None
    if b is None and v is None:
        return x
    return _wsc(x, P(b, *([None] * (x.ndim - 2)), v))


def constrain_moe(x, expert_dim: Optional[int] = None):
    """MoE dispatch-space tensors: (G, ...) with an optional expert dim.

    G (dim 0) -> batch axes; `expert_dim` (if given and divisible) -> "model"
    — e.g. (G,Tg,E,C) masks use expert_dim=2, (G,E,C,*) buffers use 1."""
    mesh, baxes = _ACT_MESH[0], _ACT_MESH[1]
    if mesh is None or not baxes:
        return x
    sizes = _mesh_axis_sizes(mesh)
    bprod = 1
    for a in baxes:
        bprod *= sizes.get(a, 1)
    g = baxes if x.shape[0] % bprod == 0 else None
    entries = [None] * x.ndim
    entries[0] = g
    if (expert_dim is not None and "model" in sizes
            and x.shape[expert_dim] % sizes["model"] == 0):
        entries[expert_dim] = "model"
    return _wsc(x, P(*entries))


def constrain_heads(x, head_dim_index: int = 2):
    """(B, S, H, hd) attention-space tensors: batch axes on dim0, heads on
    "model" when divisible.  Used where a broadcast/concat would otherwise
    lose the head sharding (e.g. MLA's shared k_pe broadcast)."""
    mesh, baxes = _ACT_MESH[0], _ACT_MESH[1]
    if mesh is None or not baxes:
        return x
    sizes = _mesh_axis_sizes(mesh)
    bprod = 1
    for a in baxes:
        bprod *= sizes.get(a, 1)
    entries = [None] * x.ndim
    entries[0] = baxes if x.shape[0] % bprod == 0 else None
    if "model" in sizes and x.shape[head_dim_index] % sizes["model"] == 0:
        entries[head_dim_index] = "model"
    return _wsc(x, P(*entries))


def cast_weight(w, dtype, logical_axes):
    """Cast an FSDP-sharded fp32 master weight to compute dtype and pin the
    bf16 copy to model-axis-only sharding: GSPMD then all-gathers the bf16
    tensor instead of gathering fp32 and converting after (observed 2x wire
    bytes on every layer's weights — §Perf iteration C-2)."""
    w = w.astype(dtype)
    mesh = _ACT_MESH[0]
    if mesh is None:
        return w
    sizes = _mesh_axis_sizes(mesh)
    msz = sizes.get("model", 1)
    entries = []
    used = False
    for ax, dim in zip(logical_axes, w.shape):
        if (not used and ax in ("heads", "kv_heads", "mlp", "experts",
                                "vocab") and dim % msz == 0 and msz > 1):
            entries.append("model")
            used = True
        else:
            entries.append(None)
    if not used:
        # no rule dim shards (e.g. Yi's 56 heads): leave GSPMD's choice
        # alone — an all-None constraint would force replication and UNDO
        # the salvage sharding (observed 2.8e10 B/step regathers in decode)
        return w
    return _wsc(w, P(*entries))


def constrain_scores(x):
    """Chunked-attention score tensors (B, KV, g, Cq, Sk).

    Preference order (§Perf iterations C-1'/C-1''):
    1. shard the KV-head dim over "model" (zero-collective attention —
       used with the GQA->MHA expansion when head counts allow);
    2. else pin Sk to "model": local partial QK^T + small softmax/ctx
       reductions (flash-decoding style) instead of K/V all-gathers."""
    mesh, baxes = _ACT_MESH[0], _ACT_MESH[1]
    if mesh is None:
        return x
    sizes = _mesh_axis_sizes(mesh)
    msz = sizes.get("model", 1)
    if msz <= 1:
        return x
    bprod = 1
    for a in baxes:
        bprod *= sizes.get(a, 1)
    b = baxes if (baxes and x.shape[0] % bprod == 0) else None
    if x.ndim >= 3 and x.shape[1] % msz == 0:
        return _wsc(x, P(b, "model", *([None] * (x.ndim - 2))))
    if x.shape[-1] % msz == 0:
        return _wsc(x, P(b, *([None] * (x.ndim - 2)), "model"))
    return x


def model_axis_size() -> int:
    mesh = _ACT_MESH[0]
    if mesh is None:
        return 1
    return _mesh_axis_sizes(mesh).get("model", 1)


# ---------------------------------------------------------------------------
# Server aggregation-state sharding (FL side)
# ---------------------------------------------------------------------------
def shard_bounds(total: int, num_shards: int,
                 align: int = 1) -> Tuple[Tuple[int, int], ...]:
    """Contiguous ``[lo, hi)`` element ranges splitting a flat ``total``-
    element vector across ``num_shards`` — the 1-D column partition the
    sharded server aggregation state lives on (ROADMAP "sharded server
    state").

    Every boundary is a multiple of ``align`` (pass the int8 scale-window
    size so quantized scale chunks never straddle shards and per-shard
    Pallas block geometry stays qchunk-aligned), so shard sizes differ by
    at most ``align``; trailing shards may be empty when ``total`` is
    small.  The per-shard fp64 accumulator is therefore at most
    ``ceil(total / num_shards)`` rounded up to ``align`` — within the
    (1/num_shards + 10%) single-host-footprint budget for any realistic
    model size.
    """
    if num_shards <= 0:
        raise ValueError(f"num_shards must be positive, got {num_shards}")
    if align <= 0:
        raise ValueError(f"align must be positive, got {align}")
    per = -(-total // num_shards)           # ceil
    per = -(-per // align) * align          # round up to alignment
    bounds = []
    for i in range(num_shards):
        lo = min(i * per, total)
        hi = min(lo + per, total)
        bounds.append((lo, hi))
    return tuple(bounds)


def agg_spec(mesh: Mesh) -> P:
    """PartitionSpec for the flat aggregation vector on an agg mesh: the
    single dimension sharded over the "data" axis."""
    return P("data" if "data" in mesh.axis_names else mesh.axis_names[0])

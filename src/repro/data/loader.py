"""Per-site data loaders with host-side double buffering."""
from __future__ import annotations

import collections
import threading
from typing import Dict, Iterator

import numpy as np

from repro.data.synthetic import SyntheticLMDataset


class FederatedDataLoader:
    """Owns one :class:`SyntheticLMDataset` per site; yields site batches.

    A tiny prefetch thread keeps one batch ahead — the CPU-container analogue
    of a real input pipeline's host-to-device overlap.
    """

    def __init__(self, vocab_size: int, seq_len: int, num_sites: int,
                 batch_per_site: int, seed: int = 0, non_iid_alpha: float = 0.5,
                 prefetch: int = 2):
        self.num_sites = num_sites
        self.batch_per_site = batch_per_site
        self._sites = [
            SyntheticLMDataset(vocab_size, seq_len, num_sequences=1 << 30,
                               seed=seed, site=s, non_iid_alpha=non_iid_alpha)
            for s in range(num_sites)
        ]
        self._queues = [collections.deque() for _ in range(num_sites)]
        self._prefetch = prefetch
        self._lock = threading.Lock()

    def num_examples(self, site: int) -> int:
        # synthetic => "virtually infinite"; report a nominal epoch size
        return 50_000

    def next_batch(self, site: int) -> Dict[str, np.ndarray]:
        q = self._queues[site]
        with self._lock:
            while len(q) < self._prefetch:
                q.append(self._sites[site].sample(self.batch_per_site))
            return q.popleft()

    def site_iterator(self, site: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch(site)

"""Synthetic data sources.

The container has no datasets; we generate deterministic, *learnable*
synthetic corpora so FL experiments exhibit real convergence:

- :class:`SyntheticLMDataset` — token sequences from a per-site Markov chain
  (non-IID across sites by construction: each site gets its own transition
  matrix mixed with a shared one).  A model that learns reduces loss well
  below uniform entropy, so training curves are meaningful.
- :func:`make_classification` — gaussian-blob classification for the
  ``flower_quickstart`` CNN/MLP experiments (the paper's CIFAR analogue).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    num_sequences: int
    seed: int = 0
    site: int = 0
    non_iid_alpha: float = 0.5   # 0 = fully site-specific chain, 1 = shared

    def __post_init__(self):
        # Shared global bigram structure + site-specific perturbation.
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 256)  # latent chain over a reduced alphabet
        shared = rng.dirichlet(np.ones(v) * 0.3, size=v)
        site_rng = np.random.default_rng(self.seed * 9973 + self.site + 1)
        local = site_rng.dirichlet(np.ones(v) * 0.3, size=v)
        a = self.non_iid_alpha
        self._trans = a * shared + (1 - a) * local
        self._trans /= self._trans.sum(axis=1, keepdims=True)
        self._latent_v = v
        self._rng = np.random.default_rng(self.seed * 31337 + self.site)

    def __len__(self) -> int:
        return self.num_sequences

    def sample(self, batch: int) -> Dict[str, np.ndarray]:
        v = self._latent_v
        toks = np.empty((batch, self.seq_len + 1), np.int32)
        state = self._rng.integers(0, v, size=batch)
        toks[:, 0] = state
        for t in range(1, self.seq_len + 1):
            # vectorized chain step
            r = self._rng.random(batch)
            cdf = np.cumsum(self._trans[state], axis=1)
            state = (r[:, None] < cdf).argmax(axis=1)
            toks[:, t] = state
        # scatter latent alphabet into the real vocab deterministically
        stride = max(self.vocab_size // v, 1)
        toks = (toks * stride) % self.vocab_size
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch_iterator(ds: SyntheticLMDataset, batch: int) -> Iterator[Dict[str, np.ndarray]]:
    while True:
        yield ds.sample(batch)


def make_classification(n: int, dim: int, classes: int, seed: int = 0,
                        site: int = 0, skew: float = 0.0, split: int = 0
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Gaussian blobs; `skew` tilts the class prior per site (label skew).

    ``split`` picks independent samples from the SAME class centers (0 =
    train, 1 = test) — centers depend only on ``seed``."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(classes, dim)) * 3.0
    site_rng = np.random.default_rng(seed * 7919 + site * 2 + split)
    prior = np.ones(classes) / classes
    if skew > 0:
        prior = site_rng.dirichlet(np.ones(classes) * (1.0 - skew + 1e-3) * 10)
    y = site_rng.choice(classes, size=n, p=prior)
    x = centers[y] + site_rng.normal(size=(n, dim))
    return x.astype(np.float32), y.astype(np.int32)

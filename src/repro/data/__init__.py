from repro.data.synthetic import SyntheticLMDataset, make_batch_iterator  # noqa: F401
from repro.data.partition import dirichlet_partition, iid_partition  # noqa: F401
from repro.data.loader import FederatedDataLoader  # noqa: F401

"""Federated partitioners (who owns which data)."""
from __future__ import annotations

from typing import List

import numpy as np


def iid_partition(num_items: int, num_sites: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(num_items)
    return [np.sort(s) for s in np.array_split(idx, num_sites)]


def dirichlet_partition(labels: np.ndarray, num_sites: int, alpha: float = 0.5,
                        seed: int = 0, min_per_site: int = 1) -> List[np.ndarray]:
    """Label-skewed non-IID split (standard FL benchmark protocol)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    site_idx: List[List[int]] = [[] for _ in range(num_sites)]
    for c in classes:
        idx_c = np.where(labels == c)[0]
        rng.shuffle(idx_c)
        props = rng.dirichlet(np.full(num_sites, alpha))
        cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
        for site, shard in enumerate(np.split(idx_c, cuts)):
            site_idx[site].extend(shard.tolist())
    # guarantee every site has something
    for s in range(num_sites):
        if len(site_idx[s]) < min_per_site:
            donor = int(np.argmax([len(x) for x in site_idx]))
            site_idx[s].extend(site_idx[donor][:min_per_site])
            del site_idx[donor][:min_per_site]
    return [np.sort(np.asarray(ix, np.int64)) for ix in site_idx]

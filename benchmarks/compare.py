"""Benchmark-trajectory CI gate.

Diffs a fresh ``BENCH_*.json`` snapshot (``benchmarks.run --quick --json``)
against the committed ``benchmarks/BENCH_baseline.json`` and fails the job
when the trajectory regresses:

- any ``agg_throughput_*`` / ``quantized_agg_*`` row whose ``mbps`` or
  ``speedup_vs_legacy`` drops more than ``--threshold`` (default 15%, env
  ``BENCH_REGRESSION_THRESHOLD``) below the baseline; ``pallas_agg_*``
  rows are gated on presence and their match flags only — their
  ``interp_mbps`` is interpret-mode (trace-overhead-bound) timing, which
  the trajectory deliberately does not hold;
- a gated row (including ``wire_bytes_*`` / ``wire_codec_convergence``)
  present and unskipped in the baseline but missing/skipped in the new
  snapshot — a bench that starts crashing or OOMing must not silently
  retire its own checks;
- any correctness flag (``match`` / ``match_tol`` / ``bitwise_match`` /
  ``within_tol`` / ``q8_match``) that is not True in the new snapshot —
  equivalence is part of the trajectory, a fast-but-wrong kernel must
  fail loudly (for ``pallas_agg_*`` the flags ARE the differential
  Pallas-vs-numpy cross-check, run on the benchmark payload sizes);
- ``wire_bytes_*`` rows whose payload ``reduction`` falls below the 3.5x
  floor the quantized wire format promises;
- ``shard_agg_*`` rows: ``mbps`` and ``overlap_speedup`` under the
  threshold like the other throughput rows, ``overlap_speedup`` under
  the absolute 1.3x floor the sharded deferred-base fold promises over
  the legacy per-arrival fold, and the ``match`` / ``shard_mem_ok``
  invariant flags (bitwise shard-count invariance, per-shard accumulator
  <= (1/shards + 10%) of the single-host footprint);
- ``hier_agg_*`` rows: presence plus the ``root_payloads_ok`` (the root
  folded <= #edges payloads for the 10k-client round) and ``match``
  (bitwise vs the flat low-memory fold) invariant flags — wall-clock is
  not gated, the O(#edges) claim is;
- ``async_ttl_*`` rows: presence plus ``async_reached`` / ``ttl_ok``
  (FedBuff reaches the sync run's quickstart loss within the sync
  wall-clock) and ``staleness_ok`` (no fold ever exceeds the staleness
  bound);
- ``sparse_delta_*`` rows: presence plus ``wire_lt_1pct`` (a 0xF5
  TopK-delta uplink at the configured fraction stays under 1% of the
  dense fp32 frame — on the synthetic 32B-param geometry this is the
  headline federated-LLM wire-cost claim) and ``match_tol`` (the
  scatter fold reconstructs within the int8 bound).

Timing rows that legitimately vary run to run (round wall-clock, straggler
ratios) are NOT gated — only throughput/speedup of the aggregation engine
and the invariant correctness flags.

Run: python -m benchmarks.compare BENCH_new.json \
        [--baseline benchmarks/BENCH_baseline.json] [--threshold 0.15]

Exit code 0 = trajectory holds, 1 = regression (messages on stderr).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

#: rows gated against the baseline: throughput/speedup fields compared
#: under the threshold, and the row itself must not vanish or go skipped
#: (wire_bytes_* / wire_codec_convergence carry no gated numeric field,
#: but losing them would silently drop the 3.5x-reduction and
#: convergence checks below)
GATED_PREFIXES = ("agg_throughput_", "quantized_agg_", "pallas_agg_",
                  "wire_bytes_", "wire_codec_convergence", "shard_agg_",
                  "hier_agg_", "async_ttl_", "tcp_round_", "sparse_delta_")
#: higher-is-better derived fields compared under the threshold
GATED_FIELDS = ("mbps", "speedup_vs_legacy", "overlap_speedup")
#: boolean derived fields that must hold wherever they appear
#: (``tcp_round_*``: ``match`` is the bitwise 16-process-vs-inproc round,
#: ``backpressure_ok`` holds the flooded server's RSS growth under the
#: ceiling — wall-clock on those rows is NOT gated, socket timing varies)
INVARIANT_FLAGS = ("match", "match_tol", "bitwise_match", "within_tol",
                   "q8_match", "shard_mem_ok", "root_payloads_ok",
                   "delivered_ok", "async_reached", "staleness_ok",
                   "ttl_ok", "backpressure_ok", "wire_lt_1pct")
#: wire_bytes_* rows must keep at least this payload reduction vs fp32
MIN_WIRE_REDUCTION = 3.5
#: shard_agg_* rows must keep at least this speedup over the legacy
#: per-arrival single-host fold (the decode/reduce overlap claim)
MIN_SHARD_OVERLAP = 1.3


def load_rows(path: str) -> Dict[str, dict]:
    with open(path) as f:
        snap = json.load(f)
    rows = snap.get("rows", {})
    if not isinstance(rows, dict) or not rows:
        raise SystemExit(f"{path}: no benchmark rows (schema mismatch?)")
    return rows


def _skipped(row: dict) -> bool:
    return row.get("us", 0) == 0 or "skipped" in row.get("derived", {})


def compare_rows(base: Dict[str, dict], new: Dict[str, dict],
                 threshold: float, prefix: str = "") -> List[str]:
    """All trajectory violations, empty when the gate passes.  A non-empty
    ``prefix`` narrows the gate to rows starting with it (the tcp-mp lane
    runs a focused ``--filter tcp`` bench, so every other gated row is
    legitimately absent from its snapshot)."""
    problems: List[str] = []
    for name in sorted(base):
        if not name.startswith(GATED_PREFIXES) or _skipped(base[name]):
            continue
        if prefix and not name.startswith(prefix):
            continue
        if name not in new or _skipped(new[name]):
            problems.append(f"{name}: gated row missing/skipped in the new "
                            f"snapshot (baseline has it)")
            continue
        bd, nd = base[name]["derived"], new[name]["derived"]
        for field in GATED_FIELDS:
            if not isinstance(bd.get(field), (int, float)):
                continue
            got = nd.get(field)
            if not isinstance(got, (int, float)):
                problems.append(f"{name}: field {field} missing in the new "
                                f"snapshot (baseline={bd[field]:.2f})")
                continue
            floor = bd[field] * (1.0 - threshold)
            if got < floor:
                drop = 100.0 * (1.0 - got / bd[field])
                problems.append(
                    f"{name}: {field} regressed {drop:.1f}% "
                    f"({bd[field]:.2f} -> {got:.2f}, floor {floor:.2f})")
    for name in sorted(new):
        derived = new[name].get("derived", {})
        if _skipped(new[name]):
            continue
        if prefix and not name.startswith(prefix):
            continue
        for flag in INVARIANT_FLAGS:
            if flag in derived and derived[flag] is not True:
                problems.append(f"{name}: {flag}={derived[flag]} — "
                                f"equivalence flag must be True")
        if name.startswith("wire_bytes_"):
            red = derived.get("reduction")
            if not isinstance(red, (int, float)) \
                    or red < MIN_WIRE_REDUCTION:
                problems.append(
                    f"{name}: payload reduction {red} below the "
                    f"{MIN_WIRE_REDUCTION}x floor")
        if name.startswith("shard_agg_"):
            ov = derived.get("overlap_speedup")
            if not isinstance(ov, (int, float)) or ov < MIN_SHARD_OVERLAP:
                problems.append(
                    f"{name}: overlap_speedup {ov} below the "
                    f"{MIN_SHARD_OVERLAP}x floor")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshot", help="fresh BENCH_*.json to check")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json")
    ap.add_argument("--threshold",
                    type=float,
                    default=float(os.environ.get(
                        "BENCH_REGRESSION_THRESHOLD", "0.15")),
                    help="allowed fractional drop per gated field "
                         "(default 0.15)")
    ap.add_argument("--prefix", default="",
                    help="narrow the gate to rows starting with this "
                         "prefix (focused lanes, e.g. --prefix tcp_round_)")
    args = ap.parse_args(argv)
    base, new = load_rows(args.baseline), load_rows(args.snapshot)
    gated = [n for n in base if n.startswith(GATED_PREFIXES)
             and not _skipped(base[n])
             and (not args.prefix or n.startswith(args.prefix))]
    problems = compare_rows(base, new, args.threshold, args.prefix)
    print(f"benchmark trajectory: {len(gated)} gated rows, "
          f"threshold {args.threshold:.0%}")
    for name in sorted(gated):
        nd = new.get(name, {}).get("derived", {})
        vals = ", ".join(f"{f}={nd[f]:.2f}"
                         for f in GATED_FIELDS + ("reduction",)
                         if isinstance(nd.get(f), (int, float)))
        print(f"  {name}: {vals or ('MISSING' if name not in new else '-')}")
    if problems:
        for p in problems:
            print(f"REGRESSION: {p}", file=sys.stderr)
        return 1
    print("trajectory holds")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark harness (deliverable d) — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  fig5_reproducibility   native vs in-FLARE round time; derived = bitwise match
  fig6_metric_streaming  per-scalar streaming latency; derived = points stored
  s41_reliable_overhead  reliable exchange at 0/10/30% drop; derived = retries
  s31_multi_job          3 concurrent vs serial jobs; derived = speedup
  strategies_convergence FedAvg/FedAdam/FedProx final loss (ecosystem claim)
  secagg_overhead        SecAgg vs plain round; derived = max param delta
  kernel_*               Pallas kernels (interpret mode) vs jnp oracle
  agg_throughput_*       flat-buffer aggregation engine: decode+FedAvg MB/s
                         across model sizes x client counts, vs the legacy
                         per-layer path (derived = speedup + equivalence)
  straggler_overlap_*    arrival-order streaming driver: round wall-clock
                         with one straggler (~max client time) or one dead
                         node (~shared deadline, NOT n x timeout; the node
                         lands in failures, the round completes)
  hier_agg_10k_*         two-tier edge aggregation at 10k simulated
                         clients: the root folds O(#edges) 0xF4 partial
                         payloads (derived = root_payloads_ok + bitwise
                         match vs the flat low-memory fold), plus the
                         SuperLink waiter-indexing completion-queue
                         micro-bench (tasks_per_s at 10k in-flight ids)
  async_ttl_*            FedBuff async mode vs sync rounds with one
                         straggler: async reaches the sync run's final
                         quickstart loss in <= the sync wall-clock
                         (ttl_ok) and never folds an update staler than
                         the bound (staleness_ok)
  wire_bytes_*           quantized wire format (0xF3 int8 + per-chunk
                         scales) vs raw fp32: per-round payload bytes both
                         directions (derived = reduction + bounded-error
                         equivalence of the aggregated round)
  quantized_agg_*        fused dequantize+accumulate aggregation straight
                         off the compressed buffers (derived = MB/s)
  pallas_agg_*           Pallas on-device aggregation kernels (interpret
                         mode on CPU) vs the numpy engine on identical
                         payloads; derived = MB/s + bitwise match (and
                         the fused int8-delta path's q8_match on the
                         small rows)
  wire_codec_convergence negotiated q8 vs flat on the quickstart task
  sparse_delta_*         structured-sparse 0xF5 TopK-delta uplinks: wire
                         bytes vs the dense fp32 frame (the <1% claim,
                         priced analytically on the 32B-param qwen3-32b
                         geometry and measured end-to-end on a real
                         payload) + the fused scatter-dequantize-
                         accumulate fold within the int8 bound
  shard_agg_*            mesh-sharded server aggregation state: q8-delta
                         round folded through per-shard accumulators with
                         the base deferred to finalize, vs the legacy
                         per-arrival single-host fold (derived = MB/s,
                         overlap_speedup, peak_rss_mb, bitwise match
                         across shard counts, per-shard memory budget)

Run: PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]

``--json`` writes the rows as a BENCH_*.json snapshot;
``python -m benchmarks.compare`` diffs one against the committed
benchmarks/BENCH_baseline.json and fails on regressions (the CI gate).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def _t(fn, n=1):
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    return (time.perf_counter() - t0) / n * 1e6, out


def bench_fig5_reproducibility(quick=False):
    from repro.core import run_in_flare, run_native
    from repro.fl import FedAvg, ServerApp, ServerConfig
    from repro.fl.quickstart import make_client_app
    from repro.runtime import FlareRuntime

    sites = ["site-1", "site-2", "site-3"]
    rounds = 2 if quick else 3

    def app():
        return ServerApp(ServerConfig(num_rounds=rounds, round_timeout=120),
                         FedAvg())

    us_native, h1 = _t(lambda: run_native(app(), lambda s: make_client_app(s),
                                          sites))
    rt = FlareRuntime()
    for s in sites:
        rt.provision_site(s)
    us_flare, h2 = _t(lambda: run_in_flare(rt, app(),
                                           lambda s: make_client_app(s), sites))
    rt.shutdown()
    match = (h1.losses() == h2.losses() and all(
        np.array_equal(a, b) for a, b in zip(h1.final_parameters,
                                             h2.final_parameters)))
    print(f"fig5_native_round,{us_native/rounds:.0f},loss={h1.losses()[-1][1]:.4f}")
    print(f"fig5_flare_round,{us_flare/rounds:.0f},bitwise_match={match}")
    return match


def bench_fig6_metric_streaming(quick=False):
    from repro.core import run_in_flare
    from repro.fl import FedAvg, ServerApp, ServerConfig
    from repro.fl.client import ClientApp
    from repro.fl.quickstart import QuickstartClient
    from repro.runtime import FlareRuntime

    sites = ["site-1", "site-2", "site-3"]
    rt = FlareRuntime()
    for s in sites:
        rt.provision_site(s)

    def client_app_fn(site):
        def with_ctx(ctx):
            w = ctx.summary_writer()
            return ClientApp(lambda cid: QuickstartClient(site, writer=w)
                             .to_client())
        return with_ctx

    t0 = time.perf_counter()
    run_in_flare(rt, ServerApp(ServerConfig(num_rounds=2, round_timeout=120),
                               FedAvg()), client_app_fn, sites)
    dt = time.perf_counter() - t0
    mc = rt.metrics(next(iter(rt._jobs)))
    points = sum(len(mc.series(t)) for t in mc.tags())
    ntags = len(mc.tags())
    rt.shutdown()
    print(f"fig6_metric_streaming,{dt/max(points,1)*1e6:.0f},points={points}"
          f";tags={ntags}")


def bench_s41_reliable_overhead(quick=False):
    from repro.runtime.reliable import ReliableMessenger
    from repro.runtime.transport import FaultSpec, Network

    n = 50 if quick else 200
    payload = b"x" * 65536
    for drop in (0.0, 0.1, 0.3):
        net = Network(FaultSpec(drop_prob=drop, seed=11))
        a = ReliableMessenger(net, "a", retry_interval=0.005,
                              default_timeout=30.0)
        b = ReliableMessenger(net, "b", retry_interval=0.005,
                              default_timeout=30.0)
        b.register_handler("w", lambda m: m.payload[:16])
        t0 = time.perf_counter()
        for i in range(n):
            a.request("b", "w", payload)
        dt = (time.perf_counter() - t0) / n * 1e6
        retries = net.stats["sent"] - 2 * n
        print(f"s41_reliable_drop{int(drop*100)},{dt:.0f},"
              f"extra_msgs={max(retries,0)};dropped={net.stats['dropped']}")
        net.close()


def bench_s31_multi_job(quick=False):
    from repro.runtime import FlareRuntime, JobSpec

    class SJob:
        def run(self, ctx):
            out = [ctx.request(s, "work", b"1") for s in sorted(ctx.sites)]
            time.sleep(0.2)
            return len(out)

    class CJob:
        def __init__(self, site):
            pass

        def run(self, ctx):
            ctx.register_handler("work", lambda m: b"done")
            ctx.stop_event.wait()

    def run_jobs(rt, concurrent):
        admin = rt.provisioner.issue("admin", "admin")
        res = {"gpu": 0.25} if concurrent else {"gpu": 1.0}
        specs = [JobSpec(name=f"j{i}", server_app_fn=lambda: SJob(),
                         client_app_fn=lambda s: CJob(s), min_sites=2,
                         resources=res) for i in range(3)]
        t0 = time.perf_counter()
        ids = [rt.submit_job(sp, admin) for sp in specs]
        for j in ids:
            rec = rt.wait(j, timeout=60)
            assert rec.status.value == "COMPLETED", rec.error
        return time.perf_counter() - t0

    rt = FlareRuntime()
    for s in ("site-1", "site-2"):
        rt.provision_site(s)
    t_serial = run_jobs(rt, concurrent=False)
    t_conc = run_jobs(rt, concurrent=True)
    rt.shutdown()
    print(f"s31_multijob_serial,{t_serial*1e6:.0f},jobs=3")
    print(f"s31_multijob_concurrent,{t_conc*1e6:.0f},"
          f"speedup={t_serial/max(t_conc,1e-9):.2f}x")


def bench_strategies(quick=False):
    from repro.core import run_native
    from repro.fl import ServerApp, ServerConfig, make_strategy
    from repro.fl.quickstart import make_client_app

    sites = ["site-1", "site-2", "site-3"]
    rounds = 2 if quick else 4
    for name in ("fedavg", "fedadam", "fedprox", "fedmedian"):
        app = ServerApp(ServerConfig(num_rounds=rounds, round_timeout=120),
                        make_strategy(name))
        us, h = _t(lambda: run_native(app, lambda s: make_client_app(
            s, lr=0.02, epochs=1, skew=0.2), sites))
        print(f"strategy_{name},{us/rounds:.0f},"
              f"final_loss={h.losses()[-1][1]:.4f}")


def bench_secagg(quick=False):
    from repro.core import run_native
    from repro.fl import (FedAvg, SecAggFedAvg, SecAggMod, ServerApp,
                          ServerConfig)
    from repro.fl.quickstart import make_client_app

    sites = ["site-1", "site-2", "site-3"]

    def seed_fn(a, b):
        import zlib
        lo, hi = sorted([a, b])
        return zlib.crc32(f"{lo}|{hi}".encode())

    us_plain, h1 = _t(lambda: run_native(
        ServerApp(ServerConfig(num_rounds=2, round_timeout=120), FedAvg()),
        lambda s: make_client_app(s), sites))
    us_sec, h2 = _t(lambda: run_native(
        ServerApp(ServerConfig(num_rounds=2, round_timeout=120),
                  SecAggFedAvg()),
        lambda s: make_client_app(s, mods=[SecAggMod(
            site=s, peers=sites, pairwise_seed_fn=seed_fn)]), sites))
    delta = max(float(np.abs(a.astype(np.float64) - b.astype(np.float64)).max())
                for a, b in zip(h1.final_parameters, h2.final_parameters))
    print(f"secagg_plain_round,{us_plain/2:.0f},baseline")
    print(f"secagg_masked_round,{us_sec/2:.0f},max_param_delta={delta:.2e}")


def bench_kernels(quick=False):
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 1, 256, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32) / 6
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    us, _ = _t(lambda: ops.flash_attention(q, k, v, block_q=64,
                                           block_kv=64).block_until_ready(), 3)
    fl = 4 * B * S * S * H * hd / 2
    print(f"kernel_flash_attention,{us:.0f},interpret_mode;flops={fl:.3g}")

    x = jnp.asarray(rng.normal(size=(1 << 16,)), jnp.float32)
    masks = jnp.asarray(rng.integers(-2**31, 2**31 - 1, size=(3, 1 << 16)),
                        jnp.int32)
    us, _ = _t(lambda: ops.secagg_mask(x, masks, 3.0).block_until_ready(), 3)
    print(f"kernel_secagg_mask,{us:.0f},interpret_mode;bytes={x.nbytes*4}")

    a = jnp.asarray(rng.uniform(0.9, 0.999, size=(2, 256, 128)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(2, 256, 128)), jnp.float32)
    h0 = jnp.zeros((2, 128), jnp.float32)
    us, _ = _t(lambda: ops.rglru_scan(a, b, h0)[0].block_until_ready(), 3)
    print(f"kernel_rglru_scan,{us:.0f},interpret_mode;steps=256")


_LEAF = 250_000                          # ~transformer-block-sized leaves
# single-entry payload cache, keyed by layout label.  The quick CI lane
# re-uses the same layouts across client counts; before this cache every
# row re-generated the arrays and re-encoded BOTH codecs from scratch, so
# the (untimed) legacy baseline setup was recomputed per row and the lane
# crept toward the 30-minute job timeout as rows grew.  One entry only —
# evicting on label change bounds peak memory to one model's payloads.
_CASE_CACHE: dict = {}


def _case_data(label, n_params, with_legacy):
    import gc

    from repro.fl.messages import FitRes, decode_fit_res, encode_fit_res

    c = _CASE_CACHE
    if c.get("label") != label:
        c.clear()
        gc.collect()
        nleaves = max(1, n_params // _LEAF)
        rng = np.random.default_rng(42)
        arrays = [rng.random(_LEAF, np.float32) for _ in range(nleaves)]
        c["nbytes"] = sum(a.nbytes for a in arrays)
        c["flat"] = encode_fit_res(FitRes(arrays, 0, {}), codec="flat")
        c["legacy"] = None
        c["current"] = [np.zeros(_LEAF, np.float32) for _ in range(nleaves)]
        del arrays
        gc.collect()
        # the label is the entry's validity marker — set LAST, so a
        # MemoryError mid-population leaves a cache the next row rebuilds
        # instead of a half-filled one it trusts
        c["label"] = label
    if with_legacy and c.get("legacy") is None:
        # rebuild the per-array payload from the flat one (zero-copy views)
        arrays = decode_fit_res(c["flat"]).parameters
        c["legacy"] = encode_fit_res(FitRes(list(arrays), 0, {}),
                                     codec="legacy")
    return c


def _agg_case(label, n_params, n_clients, with_legacy, low_memory=False):
    """Time the server aggregation hot path — TaskRes payload bytes ->
    new global model — for the flat engine and (optionally) the legacy
    per-layer path on identical inputs."""
    from repro.fl.legacy import LegacyFedAvg
    from repro.fl.messages import decode_fit_res
    from repro.fl.strategy import make_strategy

    case = _case_data(label, n_params, with_legacy)
    current = case["current"]
    nbytes = case["nbytes"]
    # all clients reuse one payload: aggregation cost is identical and the
    # bench fits in memory at 500M params x 64 clients
    payload_flat = case["flat"]
    payload_legacy = case["legacy"]
    weights = [10 + i for i in range(n_clients)]

    strat = make_strategy("fedavg", low_memory=low_memory)
    t0 = time.perf_counter()
    acc = strat.fit_accumulator(1, current)
    for c in range(n_clients):
        r = decode_fit_res(payload_flat)
        r.num_examples = weights[c]
        acc.add(f"site-{c}", r)
    flat_out, _ = acc.finalize([])
    t_flat = time.perf_counter() - t0

    derived = f"mbps={nbytes * n_clients / t_flat / 1e6:.0f}"
    if with_legacy:
        t0 = time.perf_counter()
        results = []
        for c in range(n_clients):
            r = decode_fit_res(payload_legacy)
            r.num_examples = weights[c]
            results.append((f"site-{c}", r))
        legacy_out, _ = LegacyFedAvg().aggregate_fit(1, results, [], current)
        t_leg = time.perf_counter() - t0
        match = all(np.array_equal(a, b)
                    for a, b in zip(flat_out, legacy_out))
        derived += f";speedup_vs_legacy={t_leg / t_flat:.2f}x;match={match}"
    print(f"agg_throughput_{label}_{n_clients}clients,{t_flat * 1e6:.0f},"
          f"{derived}")


def bench_agg_throughput(quick=False):
    # cases stay GROUPED BY LABEL: _CASE_CACHE holds one layout's payloads
    # and evicts on label change, so interleaving labels would regenerate
    # and re-encode the same payloads several times over
    cases = [("1M", 1_000_000, 4, True), ("1M", 1_000_000, 16, True)]
    if not quick:
        cases += [("1M", 1_000_000, 64, True), ("50M", 50_000_000, 4, True)]
    cases += [("50M", 50_000_000, 16, True)]
    if not quick:
        cases += [("50M", 50_000_000, 64, False),
                  ("500M", 500_000_000, 4, False)]
    for label, n_params, n_clients, with_legacy in cases:
        try:
            _agg_case(label, n_params, n_clients, with_legacy,
                      low_memory=n_params >= 500_000_000)
        except MemoryError:
            print(f"agg_throughput_{label}_{n_clients}clients,0,skipped=oom")
    _CASE_CACHE.clear()


def _pallas_agg_case(label, n_params, n_clients, with_q8):
    """Pallas aggregation kernels (interpret mode on this CPU container)
    vs the numpy engine on identical decoded payloads.  ``match`` is
    bitwise equality of the aggregated model; ``q8_match`` additionally
    runs the fused int8-delta path on the small rows."""
    from repro.fl import agg_kernels as K
    from repro.fl.flat import QuantParams, quantize_int8
    from repro.fl.messages import decode_fit_res

    case = _case_data(label, n_params, with_legacy=False)
    payload = case["flat"]
    nbytes = case["nbytes"]
    weights = [10.0 + i for i in range(n_clients)]
    pairs = [(decode_fit_res(payload).flat, w) for w in weights]
    layout = pairs[0][0].layout
    # a block that divides the buffer exactly skips the full-array pad
    # copy inside agg_reduce — at 50M x 16 that copy alone is ~3.4 GB
    n = layout.total_size
    block = n // 64 if n % 64 == 0 and n // 64 >= 8192 else None

    t0 = time.perf_counter()
    out_p = K.weighted_mean(pairs, layout, backend="pallas", block=block)
    t_pallas = time.perf_counter() - t0
    out_n = K.weighted_mean(pairs, layout, backend="numpy")
    match = bool(np.array_equal(out_p.buf, out_n.buf))
    # interp_mbps (NOT the gated "mbps" field): interpret-mode throughput
    # is trace/compile-overhead-bound and varies run to run — the gate
    # holds the row's presence and its match flags, not this number
    derived = (f"interp_mbps={nbytes * n_clients / t_pallas / 1e6:.0f};"
               f"match={match};interpret_mode")

    if with_q8:
        base = decode_fit_res(payload).flat
        rng = np.random.default_rng(17)
        quants = []
        for i in range(n_clients):
            delta = rng.normal(0, 1e-3, layout.total_size) \
                .astype(np.float32)
            q, s = quantize_int8(delta)
            quants.append(QuantParams(layout, "q8", q, s, is_delta=True,
                                      base=base))
        qpairs = list(zip(quants, weights))
        qp = K.weighted_mean(qpairs, layout, backend="pallas")
        qn = K.weighted_mean(qpairs, layout, backend="numpy")
        derived += f";q8_match={bool(np.array_equal(qp.buf, qn.buf))}"
    print(f"pallas_agg_{label}_{n_clients}clients,{t_pallas * 1e6:.0f},"
          f"{derived}")


def bench_pallas_agg(quick=False):
    # grouped by label like bench_agg_throughput so _CASE_CACHE's single
    # entry is reused instead of re-encoded per client count; the fused
    # q8 path only rides the 1M rows (quantizing 50M per client would
    # dominate the lane without exercising anything new)
    cases = [("1M", 1_000_000, 4, True), ("1M", 1_000_000, 16, True)]
    if not quick:
        cases += [("50M", 50_000_000, 4, False)]
    cases += [("50M", 50_000_000, 16, False)]
    for label, n_params, n_clients, with_q8 in cases:
        try:
            _pallas_agg_case(label, n_params, n_clients, with_q8)
        except Exception as e:  # noqa: BLE001 — see the re-raise below
            # jax-side allocation failure surfaces as XlaRuntimeError
            # RESOURCE_EXHAUSTED, not MemoryError — both mean "this host
            # is too small", which must become a visible skipped row, not
            # a dead benchmark run with no snapshot
            if not (isinstance(e, MemoryError)
                    or "RESOURCE_EXHAUSTED" in str(e)
                    or "Out of memory" in str(e)):
                raise
            print(f"pallas_agg_{label}_{n_clients}clients,0,skipped=oom")
    _CASE_CACHE.clear()


def _shard_agg_case(label, n_params, n_clients, shards=8):
    """Mesh-sharded server aggregation state on the realistic post-
    negotiation wire format (q8 int8 deltas against the server's own
    downlink): ``overlap_speedup`` is the sharded deferred-base fold vs
    the legacy per-arrival single-host fold on identical payloads (the
    decode/reduce restructure the overlap rides on — the fp64 base is
    read once per round at finalize instead of once per arrival, and the
    decoder thread/async kernel chain fills the freed time on multi-core
    hosts).  ``match`` is bitwise equality of finalize() across shard
    counts (8 vs 1); ``shard_mem_ok`` holds the per-shard fp64
    accumulator to <= (1/shards + 10%) of the single-host footprint."""
    import resource

    from repro.fl import agg_kernels as K
    from repro.fl.flat import QuantParams, layout_for, quantize_int8

    layout = layout_for([("float32", (n_params,))])
    rng = np.random.default_rng(23)
    bq, bs = quantize_int8(rng.random(n_params, np.float32))
    base = QuantParams(layout, "q8", bq, bs)        # the q8 downlink
    dq, ds = quantize_int8(
        rng.standard_normal(n_params, dtype=np.float32) * 1e-3)
    # all clients reuse one delta payload (same trick as agg_throughput:
    # fold cost is identical and 500M x 16 clients fits in memory)
    payload = QuantParams(layout, "q8", dq, ds, is_delta=True, base=base)
    weights = [10.0 + i for i in range(n_clients)]
    nbytes = dq.nbytes + ds.nbytes

    def fold(**kw):
        s = K.StreamingWeightedSum(layout, backend="numpy", **kw)
        t0 = time.perf_counter()
        for w in weights:
            s.add(payload, w)
        out = s.finalize()
        return time.perf_counter() - t0, out, s

    t_single, out_single, _ = fold()          # legacy per-arrival fold
    _, out_one, _ = fold(shards=1)            # deferred-base, one shard
    t_shard, out_shard, s = fold(shards=shards)
    match = bool(np.array_equal(out_shard.buf, out_one.buf))
    legacy_bitwise = bool(np.array_equal(out_shard.buf, out_single.buf))
    mem_ok = bool(s.per_shard_acc_bytes()
                  <= n_params * 8 * (1 / shards + 0.10))
    peak_rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    print(f"shard_agg_{label}_{n_clients}clients,{t_shard * 1e6:.0f},"
          f"mbps={nbytes * n_clients / t_shard / 1e6:.0f};"
          f"overlap_speedup={t_single / t_shard:.2f}x;"
          f"peak_rss_mb={peak_rss:.0f};match={match};"
          f"shard_mem_ok={mem_ok};shards={shards};"
          f"pipeline={'on' if s.overlap else 'off'};"
          f"legacy_bitwise={legacy_bitwise}")


def bench_shard_agg(quick=False):
    cases = [("50M", 50_000_000, 16)]
    if not quick:
        cases += [("500M", 500_000_000, 16)]
    for label, n_params, n_clients in cases:
        try:
            _shard_agg_case(label, n_params, n_clients)
        except MemoryError:
            print(f"shard_agg_{label}_{n_clients}clients,0,skipped=oom")


def _wire_case(label, n_params, n_clients):
    """Quantized wire format (0xF3 int8 + per-chunk scales) vs raw fp32:
    per-round payload bytes both directions, plus the fused
    dequantize+accumulate aggregation on the compressed buffers, checked
    against the fp32 path within the analytic quantization bound."""
    import gc

    from repro.fl.messages import (FitIns, FitRes, decode_fit_res,
                                   encode_fit_ins, encode_fit_res,
                                   peek_params)
    from repro.fl.strategy import make_strategy

    nleaves = max(1, n_params // _LEAF)
    rng = np.random.default_rng(7)
    model = [rng.normal(0, 0.5, (_LEAF,)).astype(np.float32)
             for _ in range(nleaves)]
    delta = [rng.normal(0, 1e-3, (_LEAF,)).astype(np.float32)
             for _ in range(nleaves)]
    result32 = [m + d for m, d in zip(model, delta)]
    weights = [10 + i for i in range(n_clients)]

    # fp32 reference round: raw 0xF1 frames both directions
    down32 = encode_fit_ins(FitIns(model, {"round": 1}), codec="flat")
    up32 = encode_fit_res(FitRes(result32, 0, {}), codec="flat")
    strat = make_strategy("fedavg")
    acc = strat.fit_accumulator(1, model)
    t0 = time.perf_counter()
    for c in range(n_clients):
        r = decode_fit_res(up32)
        r.num_examples = weights[c]
        acc.add(f"site-{c}", r)
    out32, _ = acc.finalize([])
    t_f32 = time.perf_counter() - t0
    fp32_bytes = n_clients * (len(down32) + len(up32))
    del result32, up32, down32
    gc.collect()

    # q8 round: quantized downlink; clients train from the dequantized
    # base and upload int8 DELTAS against it; the server reconstructs
    # against its own downlink bytes (zero-copy, fused into the kernels)
    t0 = time.perf_counter()
    down8 = encode_fit_ins(FitIns(model, {"round": 1, "codec": "q8"}),
                           codec="q8")
    base_client = peek_params(down8).to_flat()   # what a client decodes
    result8 = [b + d for b, d in
               zip(base_client.to_arrays(), delta)]
    up8 = encode_fit_res(FitRes(result8, 0, {}), codec="q8",
                         base=base_client)
    t_enc = time.perf_counter() - t0
    del result8, base_client, delta
    gc.collect()
    q8_bytes = n_clients * (len(down8) + len(up8))

    base_server = peek_params(down8)             # QuantParams, zero-copy
    acc = strat.fit_accumulator(1, model)
    t0 = time.perf_counter()
    for c in range(n_clients):
        r = decode_fit_res(up8)
        r.num_examples = weights[c]
        r.quant.base = base_server
        acc.add(f"site-{c}", r)
    out8, _ = acc.finalize([])
    t_q8 = time.perf_counter() - t0

    # |q8 round - fp32 round| <= downlink bound + uplink delta bound
    tol = 0.5 * (float(base_server.scales.max())
                 + float(decode_fit_res(up8).quant.scales.max())) \
        * (1 + 1e-5) + 1e-6
    err = max(float(np.abs(a.astype(np.float64) - b.astype(np.float64)).max())
              for a, b in zip(out32, out8))
    match_tol = err <= tol
    reduction = fp32_bytes / q8_bytes
    print(f"wire_bytes_{label}_{n_clients}clients,{t_enc * 1e6:.0f},"
          f"fp32_mb={fp32_bytes / 1e6:.0f};q8_mb={q8_bytes / 1e6:.0f};"
          f"reduction={reduction:.2f}x;max_err={err:.2e};"
          f"match_tol={match_tol}")
    print(f"quantized_agg_{label}_{n_clients}clients,{t_q8 * 1e6:.0f},"
          f"mbps={len(up8) * n_clients / t_q8 / 1e6:.0f};"
          f"fp32_equiv_mbps={n_params * 4 * n_clients / t_q8 / 1e6:.0f};"
          f"vs_fp32_agg={t_f32 / t_q8:.2f}x")


def bench_wire_codecs(quick=False):
    cases = [("1M", 1_000_000, 16), ("50M", 50_000_000, 16)]
    if not quick:
        cases += [("50M", 50_000_000, 64)]
    for label, n_params, n_clients in cases:
        try:
            _wire_case(label, n_params, n_clients)
        except MemoryError:
            print(f"wire_bytes_{label}_{n_clients}clients,0,skipped=oom")


def bench_wire_convergence(quick=False):
    """Negotiated q8 vs lossless flat on the quickstart task: the whole
    stack (get_properties negotiation, quantized downlink, int8 delta
    uplink, fused aggregation) with convergence within tolerance."""
    from repro.core import run_native
    from repro.fl import FedAvg, ServerApp, ServerConfig
    from repro.fl.quickstart import make_client_app

    sites = ["site-1", "site-2", "site-3"]
    rounds = 2 if quick else 3

    def run(codec):
        app = ServerApp(ServerConfig(num_rounds=rounds, round_timeout=120,
                                     codec=codec), FedAvg())
        return _t(lambda: run_native(app, lambda s: make_client_app(s),
                                     sites))

    us32, h32 = run(None)
    us8, h8 = run("q8")
    l32, l8 = h32.losses()[-1][1], h8.losses()[-1][1]
    assert h8.rounds[-1].metrics.get("wire_codec") == "q8", \
        "q8 negotiation failed"
    print(f"wire_codec_convergence,{us8 / rounds:.0f},"
          f"loss_fp32={l32:.4f};loss_q8={l8:.4f};"
          f"round_vs_fp32={us8 / max(us32, 1e-9):.2f}x;"
          f"within_tol={abs(l32 - l8) < 0.05}")


def _sparse_delta_case(label, n_params, n_clients, frac):
    """Structured-sparse 0xF5 TopK-delta round vs the dense fp32 round:
    uplink wire bytes plus the fused scatter-dequantize-accumulate fold,
    checked against a dense fold of the SAME masked update within the
    analytic int8 bound (both rounds travel identical coordinate sets —
    ``topk_indices`` is deterministic — so the residual is quantization,
    not truncation)."""
    import gc

    from repro.fl.flat import FlatParams, topk_indices
    from repro.fl.messages import FitRes, decode_fit_res, encode_fit_res
    from repro.fl.strategy import make_strategy

    nleaves = max(1, n_params // _LEAF)
    rng = np.random.default_rng(13)
    model = [rng.normal(0, 0.5, (_LEAF,)).astype(np.float32)
             for _ in range(nleaves)]
    result = [m + rng.normal(0, 1e-3, (_LEAF,)).astype(np.float32)
              for m in model]
    weights = [10 + i for i in range(n_clients)]
    base = FlatParams.from_arrays(model)
    total = base.layout.total_size

    # the coordinate set the encoder will pick: same selection function
    # on the same fp32 quantity (result - base), same k rounding
    mag = np.abs(np.concatenate(result) - np.concatenate(model))
    idx = topk_indices(mag, max(1, int(np.ceil(frac * total))))
    del mag
    keep = np.zeros(total, bool)
    keep[idx] = True
    masked = [np.where(keep[i * _LEAF:(i + 1) * _LEAF], r, m)
              for i, (m, r) in enumerate(zip(model, result))]

    # dense fp32 reference round over the masked update
    up32 = encode_fit_res(FitRes(masked, 0, {}), codec="flat")
    strat = make_strategy("fedavg")
    acc = strat.fit_accumulator(1, model)
    for c in range(n_clients):
        r = decode_fit_res(up32)
        r.num_examples = weights[c]
        acc.add(f"site-{c}", r)
    out32, _ = acc.finalize([])
    fp32_bytes = len(up32)
    del up32, masked
    gc.collect()

    # sparse round: same full result, the encoder's TopK keeps `idx`
    t0 = time.perf_counter()
    up_sp = encode_fit_res(FitRes(result, 0, {}), codec="sparse",
                           base=base, sparse_frac=frac)
    t_enc = time.perf_counter() - t0
    del result
    gc.collect()

    acc = strat.fit_accumulator(1, model)
    t0 = time.perf_counter()
    for c in range(n_clients):
        r = decode_fit_res(up_sp)
        r.num_examples = weights[c]
        r.sparse.base = base
        acc.add(f"site-{c}", r)
    out_sp, _ = acc.finalize([])
    t_fold = time.perf_counter() - t0

    sp = decode_fit_res(up_sp).sparse
    tol = 0.5 * float(sp.scales.max()) * (1 + 1e-5) + 1e-6
    err = max(float(np.abs(a.astype(np.float64)
                           - b.astype(np.float64)).max())
              for a, b in zip(out32, out_sp))
    ratio = len(up_sp) / fp32_bytes
    print(f"sparse_delta_{label}_wire,{(t_enc + t_fold) * 1e6:.0f},"
          f"fp32_mb={fp32_bytes / 1e6:.1f};sparse_mb={len(up_sp) / 1e6:.2f};"
          f"frac={frac};wire_pct={100 * ratio:.3f};"
          f"wire_lt_1pct={ratio < 0.01};nnz={sp.nnz};"
          f"fold_mbps={n_params * 4 * n_clients / t_fold / 1e6:.0f};"
          f"max_err={err:.2e};match_tol={err <= tol}")


def bench_sparse_delta(quick=False):
    """0xF5 structured-sparse delta codec: the federated-LLM wire-cost
    claim.  ``sparse_delta_32b_cfg_wire`` prices a TopK uplink for the
    registry qwen3-32b geometry analytically off the abstract layout (no
    32B-param allocation — index/value/scale stream widths are fixed by
    the frame format); ``sparse_delta_100m_wire`` runs the real
    encode + scatter fold on an allocated payload."""
    import math

    import jax

    from repro.config import get_model_config
    from repro.fl.flat import QCHUNK
    from repro.models import build_model

    frac = 1e-3
    t0 = time.perf_counter()
    leaves = jax.tree.leaves(build_model(
        get_model_config("qwen3-32b")).abstract())
    total = sum(int(np.prod(l.shape)) for l in leaves)
    us = (time.perf_counter() - t0) * 1e6
    nnz = int(total * frac)
    # payload streams: fp32 dense vs int64 indices + int8 values +
    # fp32 per-QCHUNK scales (the msgpack layout header is shared by
    # both frames and vanishes at this scale)
    fp32_bytes = total * 4
    sparse_bytes = nnz * 8 + nnz * 1 + 4 * math.ceil(nnz / QCHUNK)
    ratio = sparse_bytes / fp32_bytes
    print(f"sparse_delta_32b_cfg_wire,{max(us, 1):.0f},"
          f"params_b={total / 1e9:.1f};fp32_gb={fp32_bytes / 1e9:.1f};"
          f"sparse_mb={sparse_bytes / 1e6:.0f};frac={frac};"
          f"wire_pct={100 * ratio:.3f};wire_lt_1pct={ratio < 0.01}")

    n_params = 20_000_000 if quick else 100_000_000
    label = "100m"                      # row name is baseline-stable
    try:
        _sparse_delta_case(label, n_params, 8, frac)
    except MemoryError:
        print(f"sparse_delta_{label}_wire,0,skipped=oom")


def _straggler_case(n_clients, delta, timeout, dead=False, rounds=2):
    """Round wall-clock with one straggler (delayed by ``delta``) or one
    dead node among ``n_clients``, through the arrival-order streaming
    driver.  Returns (seconds_per_round, failures_per_round)."""
    import threading

    from repro.core.superlink import (NativeConnection, SuperLink,
                                      SuperLinkDriver, SuperNode)
    from repro.fl import ClientApp, FedAvg, NumPyClient, ServerApp, \
        ServerConfig

    shape = (250_000,)                      # ~1 MB fp32 model

    class C(NumPyClient):
        def __init__(self, v, delay=0.0, dead_ev=None):
            self.v, self.delay, self.dead_ev = float(v), delay, dead_ev

        def fit(self, parameters, config):
            if self.dead_ev is not None:
                self.dead_ev.wait()
            if self.delay:
                time.sleep(self.delay)
            return [np.full(shape, self.v, np.float32)], 10, {}

    class NoEval(FedAvg):
        def configure_evaluate(self, rnd, parameters, nodes):
            return {}

    dead_ev = threading.Event() if dead else None
    link = SuperLink()
    nodes = []
    for i in range(n_clients):
        straggler = i == n_clients - 1
        c = C(i + 1, delay=delta if straggler and not dead else 0.0,
              dead_ev=dead_ev if straggler and dead else None)
        nodes.append(SuperNode(f"site-{i}",
                               ClientApp(lambda cid, c=c: c.to_client()),
                               NativeConnection(link)))
    for n in nodes:
        n.start()
    try:
        app = ServerApp(ServerConfig(num_rounds=rounds,
                                     round_timeout=timeout),
                        NoEval(initial_parameters=[np.zeros(shape,
                                                            np.float32)]))
        driver = SuperLinkDriver(link, expected_nodes=n_clients)
        t0 = time.perf_counter()
        h = app.run(driver)
        dt = (time.perf_counter() - t0) / rounds
    finally:
        if dead_ev is not None:
            dead_ev.set()
        for n in nodes:
            n.stop()
    return dt, len(h.rounds[-1].failures)


def bench_straggler_overlap(quick=False):
    """Fault-tolerance trajectory: with one client delayed by delta the
    round ends at ~max(client time) (decode+accumulate overlaps the
    straggler, nobody waits out the deadline); with one dead client the
    round ends at the SHARED deadline (not n_clients x timeout) and the
    node lands in failures instead of aborting the round."""
    delta, timeout = (0.3, 1.0) if quick else (0.5, 1.5)
    sizes = [4] if quick else [4, 16]
    for n in sizes:
        dt, nfail = _straggler_case(n, delta, timeout=10.0, dead=False)
        print(f"straggler_overlap_{n}clients,{dt*1e6:.0f},"
              f"delta_ms={delta*1e3:.0f};round_over_delta={dt/delta:.2f}x;"
              f"failures={nfail}")
        dt, nfail = _straggler_case(n, delta, timeout=timeout, dead=True)
        # legacy driver: the dead node's pull burned ~1x timeout then the
        # TimeoutError ABORTED the run (and up to n x timeout with every
        # node dead); now the round completes at the shared deadline
        print(f"straggler_deadnode_{n}clients,{dt*1e6:.0f},"
              f"timeout_ms={timeout*1e3:.0f};"
              f"round_over_timeout={dt/timeout:.2f}x;"
              f"legacy_behavior=abort;failures={nfail}")


def bench_hier_agg(quick=False):
    """Two-tier topology at 10k simulated clients (ISSUE 8 tentpole):
    the root server folds exactly ``num_edges`` 0xF4 partial-aggregate
    payloads per round instead of 10k leaf results, and — because every
    client update is dyadic-exact (integers/256, weight 1) — the
    aggregate is bitwise-equal to the flat low-memory fold over all 10k
    updates.  Also rows the SuperLink O(1) waiter indexing: completion-
    queue throughput with 10k in-flight task ids (the old pull_any
    rescanned every pending id per wakeup: O(n) per result, O(n^2) per
    round)."""
    import msgpack

    from repro.core.interop import run_hierarchical
    from repro.core.superlink import SuperLink
    from repro.fl import ClientApp, FedAvg, NumPyClient, ServerApp, \
        ServerConfig
    from repro.fl import agg_kernels as K
    from repro.fl.messages import FitRes
    from repro.fl.strategy import _flat_of

    n_clients, num_edges = 10_000, 8
    shapes = [(64, 16), (16,)]
    zeros = [np.zeros(s, np.float32) for s in shapes]

    def update(idx):
        rng = np.random.default_rng(idx)
        return [rng.integers(-512, 512, s).astype(np.float32) / 256.0
                for s in shapes]

    class Toy(NumPyClient):
        def __init__(self, site):
            self.idx = int(site.rsplit("-", 1)[1])

        def fit(self, parameters, config):
            return [p + u for p, u in zip(parameters, update(self.idx))], \
                1, {}

        def evaluate(self, parameters, config):
            return 0.0, 1, {}

    sites = [f"c-{i:05d}" for i in range(n_clients)]
    app = ServerApp(ServerConfig(num_rounds=1, round_timeout=300.0),
                    FedAvg(initial_parameters=zeros))
    t0 = time.perf_counter()
    h = run_hierarchical(
        app, lambda s: ClientApp(client_fn=lambda cid, s=s:
                                 Toy(s).to_client()),
        sites, num_edges=num_edges, edge_timeout=300.0)
    dt = time.perf_counter() - t0
    r = h.rounds[0]
    payloads = r.metrics["num_payloads"]
    ok = (payloads <= num_edges and r.metrics["num_clients"] == n_clients
          and not r.failures)
    # flat low-memory reference: ONE streaming fold over all 10k updates
    # (same arithmetic the flat server runs), no transport
    acc = K.StreamingWeightedSum(_flat_of(FitRes(zeros, 1, {})).layout)
    for i in range(n_clients):
        acc.add(_flat_of(FitRes(update(i), 1, {})), 1.0)
    want = acc.finalize().to_arrays()
    match = all(np.array_equal(a, b)
                for a, b in zip(h.final_parameters, want))
    print(f"hier_agg_10k_{num_edges}edges,{dt * 1e6:.0f},"
          f"clients={n_clients};edges={num_edges};"
          f"root_payloads={payloads};root_payloads_ok={ok};match={match}")

    # waiter-indexing micro-bench: one cursor over 10k in-flight ids,
    # every arrival routed O(1) (legacy pull_any: O(n) rescan per result)
    n_tasks = 2_000 if quick else 10_000
    link = SuperLink()
    tids = [link.push_task_ins("n0", b"") for _ in range(n_tasks)]
    w = link.register_waiter(tids)
    t0 = time.perf_counter()
    for tid in tids:
        link.fleet_unary("push_task_res",
                         msgpack.packb({"id": tid, "res": b"r"},
                                       use_bin_type=True))
    got = 0
    deadline = time.monotonic() + 60.0
    while got < n_tasks and link.waiter_next(w, deadline) is not None:
        got += 1
    dt = time.perf_counter() - t0
    link.release_waiter(w, tids)
    link.discard(tids)
    print(f"hier_agg_10k_pull,{dt / n_tasks * 1e6:.3f},"
          f"tasks_per_s={n_tasks / dt:.0f};n={n_tasks};"
          f"delivered_ok={got == n_tasks}")


def bench_async_ttl(quick=False):
    """FedBuff async mode vs sync rounds on the quickstart task with one
    straggler (ISSUE 8 acceptance): the async run must reach the sync
    run's final loss within the sync wall-clock (``ttl_ok``) while never
    folding an update staler than the bound (``staleness_ok``) — the
    straggler tax the buffered fold removes."""
    from repro.core.superlink import (NativeConnection, SuperLink,
                                      SuperLinkDriver, SuperNode)
    from repro.fl import ClientApp, FedAvg, ServerApp, ServerConfig
    from repro.fl.quickstart import QuickstartClient

    delay = 0.4
    sync_rounds = 2 if quick else 3
    async_rounds = 4 if quick else 6          # version advances
    sites = ["site-1", "site-2", "site-3", "site-4"]

    class Straggler(QuickstartClient):
        def fit(self, parameters, config):
            time.sleep(delay)
            return super().fit(parameters, config)

    def run(config):
        link = SuperLink()
        nodes = []
        for i, s in enumerate(sites):
            cls = Straggler if i == len(sites) - 1 else QuickstartClient
            nodes.append(SuperNode(
                s, ClientApp(client_fn=lambda cid, c=cls, s=s:
                             c(s).to_client()),
                NativeConnection(link)))
        for n in nodes:
            n.start()
        try:
            t0 = time.perf_counter()
            h = ServerApp(config, FedAvg()).run(
                SuperLinkDriver(link, expected_nodes=len(sites)))
            return time.perf_counter() - t0, h
        finally:
            for n in nodes:
                n.stop()

    t_sync, h_sync = run(ServerConfig(num_rounds=sync_rounds,
                                      round_timeout=120.0))
    loss_sync = h_sync.losses()[-1][1]
    target = loss_sync + 0.05                 # wire_codec_convergence tol

    max_staleness = 4
    # evaluate the final version only: an evaluate task queues behind the
    # straggler's in-flight delayed fit on its single-threaded SuperNode,
    # so mid-run evaluates would re-impose the very straggler tax the
    # buffered fold removes
    t_async, h_async = run(ServerConfig(
        num_rounds=async_rounds, round_timeout=120.0, async_mode=True,
        async_buffer_k=2, async_max_staleness=max_staleness,
        async_eval_every=async_rounds))
    async_losses = [l for _, l in h_async.losses()]
    reached = bool(async_losses and min(async_losses) <= target)
    staleness_ok = all(r.metrics.get("max_folded_staleness", 0)
                       <= max_staleness for r in h_async.rounds)
    ttl_ok = bool(reached and t_async <= t_sync)
    folds = h_async.rounds[-1].metrics.get("async_folded", 0)
    print(f"async_ttl_quickstart,{t_async * 1e6:.0f},"
          f"sync_s={t_sync:.2f};async_s={t_async:.2f};"
          f"loss_sync={loss_sync:.4f};loss_async={min(async_losses):.4f};"
          f"folds={folds};async_reached={reached};"
          f"staleness_ok={staleness_ok};ttl_ok={ttl_ok}")


# --------------------------------------------------------------------------
# TCP transport (ISSUE 9): the 16-process socket round + backpressure flood
# --------------------------------------------------------------------------
def _tcp_det_client_app(node_id):
    """Picklable ClientApp factory for spawned SuperNode processes: a
    deterministic numpy update (fit adds a site-derived constant), so the
    tcp-vs-inproc aggregate can be compared bitwise without training."""
    import numpy as np

    from repro.fl import ClientApp, NumPyClient

    class Det(NumPyClient):
        def __init__(self, cid):
            self.idx = int(cid.rsplit("-", 1)[-1])

        def fit(self, parameters, config):
            out = [np.asarray(p, np.float32) + np.float32(self.idx + 1)
                   for p in parameters]
            return out, 10 + self.idx, {}

        def evaluate(self, parameters, config):
            loss = float(sum(np.abs(np.asarray(p)).sum()
                             for p in parameters))
            return loss, 10 + self.idx, {}

    return ClientApp(lambda cid, n=node_id: Det(n).to_client())


def _child_hwm_mb():
    """This process's RSS high-water mark in MB.  NOT ru_maxrss: on this
    kernel a spawned child inherits the parent's ru_maxrss watermark, so
    after a big parent bench the child would report the parent's peak and
    the growth measurement would be vacuously zero.  /proc VmHWM is reset
    by exec and tracks only the child's own footprint."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM"):
                    return int(line.split(":")[1].split()[0]) / 1024
    except OSError:
        pass
    import resource
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024


def _tcp_backpressure_server(q, n_peers, per_peer, credits, consume_sleep):
    """Spawned slow-consumer server: reports its own RSS high-water so the
    measurement is uncontaminated by the parent's 16 client threads."""
    from repro.core.transport import TcpSuperLink

    link = TcpSuperLink("127.0.0.1", 0, credits_per_peer=credits,
                        heartbeat_timeout=120.0)
    base_mb = _child_hwm_mb()
    q.put(("ready", link.address, base_mb))
    remaining = {f"flood-{i}-{k}" for i in range(n_peers)
                 for k in range(per_peer)}
    got = 0
    give_up = time.monotonic() + 600
    while remaining and time.monotonic() < give_up:
        item = link.pull_any(list(remaining), time.monotonic() + 60)
        if item is None:
            break
        remaining.discard(item[0])
        got += 1
        time.sleep(consume_sleep)        # the deliberately slow consumer
    peak_mb = _child_hwm_mb()
    link.close()
    q.put(("done", got, peak_mb))


def bench_tcp_round(quick=False):
    """Real-socket transport rows (both gated on presence + flags):

    ``tcp_round_16proc_quickstart`` — a 2-round deterministic fleet round
    over 16 spawned SuperNode processes vs the identical in-proc fleet;
    ``match`` is bitwise equality of the two loss histories.

    ``tcp_round_16proc_backpressure`` — 16 fast client threads flood a
    deliberately slow spawned server with results through a small credit
    window; ``backpressure_ok`` holds the server's RSS *growth* under a
    ceiling that unthrottled buffering of the flood would blow through —
    the sender blocks, the server does not balloon.
    """
    import multiprocessing as mp

    from repro.core.superlink import (NativeConnection, SuperLink,
                                      SuperLinkDriver, SuperNode)
    from repro.core.transport import (TcpFleetConnection, TcpSuperLink,
                                      run_supernode)
    from repro.fl import ServerApp, ServerConfig, make_strategy

    n_procs, rounds = 16, 2
    sites = [f"proc-{i}" for i in range(n_procs)]

    def server_app():
        initial = [np.linspace(-1.0, 1.0, 32, np.float32).reshape(8, 4),
                   np.zeros(8, np.float32)]
        return ServerApp(ServerConfig(num_rounds=rounds, round_timeout=120),
                         make_strategy("fedavg",
                                       initial_parameters=initial))

    # in-proc reference fold (threads, same apps)
    link = SuperLink()
    nodes = [SuperNode(s, _tcp_det_client_app(s), NativeConnection(link))
             for s in sites]
    for n in nodes:
        n.start()
    try:
        t0 = time.perf_counter()
        h_ref = server_app().run(SuperLinkDriver(link,
                                                 expected_nodes=n_procs))
        t_inproc = time.perf_counter() - t0
    finally:
        for n in nodes:
            n.stop()

    ctx = mp.get_context("spawn")            # JAX threads do not fork
    with TcpSuperLink("127.0.0.1", 0, poll_wait=1.0,
                      heartbeat_timeout=60.0) as tlink:
        host, port = tlink.address
        procs = [ctx.Process(target=run_supernode,
                             args=(host, port, s, _tcp_det_client_app),
                             kwargs=dict(run_seconds=600.0,
                                         max_disconnected=10.0),
                             daemon=True) for s in sites]
        for p in procs:
            p.start()
        try:
            join_deadline = time.monotonic() + 300
            while len(tlink.node_ids()) < n_procs \
                    and time.monotonic() < join_deadline:
                time.sleep(0.2)
            t0 = time.perf_counter()
            h_tcp = server_app().run(SuperLinkDriver(
                tlink, expected_nodes=n_procs))
            t_tcp = time.perf_counter() - t0
        finally:
            tlink.close()
            for p in procs:
                p.join(timeout=30)
                if p.is_alive():
                    p.kill()
    match = h_tcp.losses() == h_ref.losses()
    print(f"tcp_round_16proc_quickstart,{t_tcp / rounds * 1e6:.0f},"
          f"procs={n_procs};rounds={rounds};"
          f"vs_inproc={t_tcp / max(t_inproc, 1e-9):.2f}x;match={match}")

    # ---- backpressure flood: slow spawned server, 16 fast pushers ----
    n_peers = 16
    per_peer = 16 if quick else 32
    payload = bytes(512 << 10)               # 512 KiB per result
    credits = 1 << 20                        # 1 MiB window per peer
    total_mb = n_peers * per_peer * len(payload) / 1e6
    # held bytes are bounded by peers x 2x-window overshoot (~32 MB);
    # the ceiling leaves allocator headroom yet sits far under the flood
    ceiling_mb = 128.0
    q = ctx.Queue()
    server = ctx.Process(target=_tcp_backpressure_server,
                         args=(q, n_peers, per_peer, credits, 0.005),
                         daemon=True)
    server.start()
    tag, (host, port), base_mb = q.get(timeout=120)
    assert tag == "ready"

    def flood(i):
        conn = TcpFleetConnection(host, port, f"flood-{i}",
                                  request_timeout=600.0)
        try:
            for k in range(per_peer):
                conn.push_result(f"flood-{i}-{k}", payload)
        finally:
            conn.close()

    import threading
    t0 = time.perf_counter()
    threads = [threading.Thread(target=flood, args=(i,))
               for i in range(n_peers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tag, got, peak_mb = q.get(timeout=600)
    server.join(timeout=30)
    if server.is_alive():
        server.kill()
    dt = time.perf_counter() - t0
    growth = peak_mb - base_mb
    ok = bool(got == n_peers * per_peer and growth <= ceiling_mb)
    print(f"tcp_round_16proc_backpressure,{dt * 1e6:.0f},"
          f"pushed_mb={total_mb:.0f};window_mb={credits / 1e6:.0f};"
          f"peak_rss_mb={peak_mb:.0f};rss_growth_mb={growth:.0f};"
          f"ceiling_mb={ceiling_mb:.0f};delivered={got};"
          f"backpressure_ok={ok}")


class _Tee:
    """stdout wrapper that records everything written, so the CSV rows can
    be re-emitted as a structured ``BENCH_*.json`` snapshot."""

    def __init__(self, inner):
        self.inner = inner
        self.chunks = []

    def write(self, s):
        self.inner.write(s)
        self.chunks.append(s)
        return len(s)

    def flush(self):
        self.inner.flush()

    def text(self):
        return "".join(self.chunks)


def _parse_derived(derived: str):
    """``k=v;k=v`` (plus bare flags) -> dict with floats/bools parsed."""
    out = {}
    for tok in derived.split(";"):
        tok = tok.strip()
        if not tok:
            continue
        if "=" not in tok:
            out[tok] = True
            continue
        k, v = tok.split("=", 1)
        if v in ("True", "False"):
            out[k] = v == "True"
            continue
        try:
            out[k] = float(v.rstrip("x"))
        except ValueError:
            out[k] = v
    return out


def rows_from_csv(text: str):
    """Parse ``name,us_per_call,derived`` lines into the snapshot schema
    (shared with benchmarks.compare)."""
    import re

    rows = {}
    for line in text.splitlines():
        m = re.match(r"^([a-z][A-Za-z0-9_]*),([0-9.eE+-]+),(.*)$", line)
        if not m or m.group(1) == "name":
            continue
        rows[m.group(1)] = {"us": float(m.group(2)), "raw": m.group(3),
                            "derived": _parse_derived(m.group(3))}
    return rows


def main() -> None:
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--filter", metavar="SUBSTR", default=None,
                    help="only run benches whose name contains SUBSTR "
                         "(e.g. --filter tcp for the CI tcp-mp lane)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as a BENCH_*.json snapshot "
                         "(consumed by benchmarks.compare in CI)")
    args, _ = ap.parse_known_args()
    benches = [
        ("fig5_reproducibility", bench_fig5_reproducibility),
        ("fig6_metric_streaming", bench_fig6_metric_streaming),
        ("s41_reliable_overhead", bench_s41_reliable_overhead),
        ("s31_multi_job", bench_s31_multi_job),
        ("strategies", bench_strategies),
        ("secagg", bench_secagg),
        ("kernels", bench_kernels),
        ("agg_throughput", bench_agg_throughput),
        ("pallas_agg", bench_pallas_agg),
        ("shard_agg", bench_shard_agg),
        ("wire_codecs", bench_wire_codecs),
        ("wire_convergence", bench_wire_convergence),
        ("sparse_delta", bench_sparse_delta),
        ("straggler_overlap", bench_straggler_overlap),
        ("hier_agg", bench_hier_agg),
        ("async_ttl", bench_async_ttl),
        ("tcp_round", bench_tcp_round),
    ]
    if args.filter:
        benches = [(n, fn) for n, fn in benches if args.filter in n]
        if not benches:
            raise SystemExit(f"--filter {args.filter!r} matches no bench")
    tee = _Tee(sys.stdout)
    if args.json:
        sys.stdout = tee
    ok = True
    try:
        print("name,us_per_call,derived")
        for name, fn in benches:
            out = fn(args.quick)
            if name == "fig5_reproducibility":
                ok = out
    finally:
        sys.stdout = tee.inner
    if args.json:
        snap = {"schema": 1, "quick": bool(args.quick),
                "rows": rows_from_csv(tee.text())}
        with open(args.json, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"wrote {args.json} ({len(snap['rows'])} rows)")
    if not ok:
        print("ERROR: fig5 reproducibility failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
